"""Wing decomposition (edge peeling, paper section 7): the host
reference path AND the shared-engine edge-axis path (DESIGN.md §10),
differentially pinned to the sequential edge-peel oracle.

``wing_bup_oracle`` is the ground truth the whole stack is tested
against: the engine path (``wing_decompose_engine`` —
``DELTA_RULES["edge"]`` on `engine/peel_loop.py`'s CD range-peel and
batched level-FD loops) must be BIT-IDENTICAL to it on every test graph
in every dispatch/backend/side combination, with the same O(1)
host-round-trip bound as the vertex axis.
"""
import json
import subprocess
import sys

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st
from conftest import GRAPH_CASES

from repro.api import EngineConfig, Executor, WingDecomposition
from repro.core.engine import tip_decompose, wing_decompose_engine
from repro.core.engine.peel_loop import DELTA_RULES, ReceiptConfig
from repro.core.graph import BipartiteGraph, random_bipartite
from repro.core.peeling import bup_oracle
from repro.core.wing import (
    edge_butterfly_counts,
    wing_bup_oracle,
    wing_decompose,
)

SMALL_BLOCKS = (8, 8, 8)
INTERP_BLOCKS = (8, 8, 16)


def _cfg(backend="xla", **kw):
    base = dict(
        num_partitions=4,
        kernel_blocks=INTERP_BLOCKS if backend.startswith("interpret")
        else SMALL_BLOCKS,
        backend=backend,
    )
    base.update(kw)
    return ReceiptConfig(**base)


# oracle cache: the oracle recounts after every single edge peel
# (O(m) matmul rounds) — compute each case once for the whole module
_ORACLE = {}


def _oracle(case):
    if case not in _ORACLE:
        _ORACLE[case] = wing_bup_oracle(GRAPH_CASES[case]())[0]
    return _ORACLE[case]


# --------------------------------------------------------------------- #
# ground truth sanity (host reference path, core/wing.py)
# --------------------------------------------------------------------- #
def test_k22_is_a_1_wing():
    g = BipartiteGraph.from_edges(2, 2, [0, 0, 1, 1], [0, 1, 0, 1])
    psi, _ = wing_bup_oracle(g)
    assert psi.tolist() == [1, 1, 1, 1]
    pr, _ = wing_decompose(g, num_partitions=2)
    assert pr.tolist() == [1, 1, 1, 1]


def test_edge_counts_closed_form():
    """b(u,v) equals brute-force butterfly enumeration per edge."""
    g = random_bipartite(10, 8, 0.4, seed=1)
    a = g.dense(dtype=np.int64)[: g.n_u, : g.n_v]
    b = edge_butterfly_counts(a)
    for e in range(g.m):
        u, v = g.edges_u[e], g.edges_v[e]
        cnt = 0
        for u2 in range(g.n_u):
            if u2 == u or not a[u2, v]:
                continue
            for v2 in range(g.n_v):
                if v2 == v:
                    continue
                if a[u, v2] and a[u2, v2]:
                    cnt += 1
        assert b[u, v] == cnt, (u, v, b[u, v], cnt)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
@pytest.mark.parametrize("p", [2, 4, 8])
def test_wing_matches_oracle(seed, p):
    g = random_bipartite(12, 9, 0.35, seed=seed)
    po, _ = wing_bup_oracle(g)
    pr, stats = wing_decompose(g, num_partitions=p)
    np.testing.assert_array_equal(po, pr)
    assert stats.num_subsets >= 1


def test_wing_sync_reduction():
    """Coarse edge ranges cut sync rounds vs per-edge peeling."""
    g = random_bipartite(16, 12, 0.4, seed=7)
    _, rounds_seq = wing_bup_oracle(g)
    _, stats = wing_decompose(g, num_partitions=4)
    assert stats.rho_cd < rounds_seq


# --------------------------------------------------------------------- #
# the differential suite: shared-engine edge axis vs the oracle
# (every GRAPH_CASE x dispatch x backend x side must be bit-identical)
# --------------------------------------------------------------------- #
_HEAVY = {"powerlaw", "vhub"}


def _diff_params():
    out = []
    for case in sorted(GRAPH_CASES):
        for dispatch in ("subset", "graph"):
            for backend in ("xla", "interpret"):
                for side in ("U", "V"):
                    marks = ([pytest.mark.slow] if case in _HEAVY
                             and (backend != "xla" or side != "U") else [])
                    out.append(pytest.param(
                        case, dispatch, backend, side,
                        id=f"{case}-{dispatch}-{backend}-{side}",
                        marks=marks))
    return out


@pytest.mark.parametrize("case,dispatch,backend,side", _diff_params())
def test_engine_wing_matches_oracle(case, dispatch, backend, side):
    g = GRAPH_CASES[case]()
    psi_o = _oracle(case)
    psi, stats = wing_decompose_engine(
        g, _cfg(backend=backend, cd_dispatch=dispatch), side=side)
    np.testing.assert_array_equal(psi, psi_o)
    if g.m:
        assert stats.num_subsets >= 1


@pytest.mark.parametrize("case", sorted(set(GRAPH_CASES) - _HEAVY))
def test_engine_wing_graph_dispatch_o1_round_trips(case):
    """The graph dispatch's headline contract carries to the edge axis:
    O(1) blocking host syncs per graph, independent of psi_max and P
    (the edge sweep cannot overflow — oversized peel sets route to the
    closed-form recount in-body, so no overflow replays exist)."""
    g = GRAPH_CASES[case]()
    psi, stats = wing_decompose_engine(
        g, _cfg(cd_dispatch="graph", num_partitions=8))
    np.testing.assert_array_equal(psi, _oracle(case))
    assert stats.host_round_trips <= 4, stats.host_round_trips


@pytest.mark.parametrize("p", [1, 2, 4, 16])
def test_engine_wing_partition_sweep(p):
    g = GRAPH_CASES["er_small"]()
    psi, stats = wing_decompose_engine(g, _cfg(num_partitions=p))
    np.testing.assert_array_equal(psi, _oracle("er_small"))
    assert stats.num_subsets <= max(p, 1)


def test_engine_wing_huc_off_still_exact():
    """use_huc=False pins the edge sweep to always-recount (the
    closed-form HUC path); psi must not change."""
    g = GRAPH_CASES["er_dense"]()
    psi, stats = wing_decompose_engine(g, _cfg(use_huc=False))
    np.testing.assert_array_equal(psi, _oracle("er_dense"))
    assert stats.huc_recounts == 0   # counter tracks HUC *decisions*


def test_engine_wing_bounds_monotone_and_cover():
    g = GRAPH_CASES["er_small"]()
    psi, stats = wing_decompose_engine(g, _cfg(num_partitions=8))
    b = stats.bounds
    assert all(b[i] <= b[i + 1] for i in range(len(b) - 1))
    assert b[0] == 0.0
    assert psi.max() < b[-1]


def test_delta_rules_registry():
    """The axis abstraction is the tentpole: both delta rules are
    registered and the edge rule owns mutable geometry."""
    assert set(DELTA_RULES) == {"vertex", "edge"}
    assert DELTA_RULES["edge"].mutable_geom
    assert not DELTA_RULES["vertex"].mutable_geom


# --------------------------------------------------------------------- #
# API layer: workload="wing" through Planner/Executor (DESIGN.md §6+§10)
# --------------------------------------------------------------------- #
def _api_cfg(**kw):
    base = dict(workload="wing", kernel_blocks=SMALL_BLOCKS,
                backend="xla", num_partitions=4)
    base.update(kw)
    return EngineConfig(**base)


def test_executor_wing_decompose_and_verify():
    g = GRAPH_CASES["er_small"]()
    ex = Executor(_api_cfg())
    wd = ex.decompose(g, verify=True)
    assert isinstance(wd, WingDecomposition)
    np.testing.assert_array_equal(wd.edge_wing, _oracle("er_small"))
    assert wd.stats.verified and wd.stats.verify_checks >= 3
    assert wd.plan.workload == "wing"
    assert wd.plan.m_pad >= g.m
    # k-wing hierarchy query
    sub, keep = wd.subgraph_at(max(wd.max_psi(), 1))
    assert sub.m == len(keep)
    assert (wd.edge_wing[keep] >= max(wd.max_psi(), 1)).all()


def test_executor_wing_cache_and_signature():
    g = GRAPH_CASES["er_small"]()
    ex = Executor(_api_cfg())
    wd1 = ex.decompose(g)
    wd2 = ex.decompose(g)
    np.testing.assert_array_equal(wd1.edge_wing, wd2.edge_wing)
    cs = ex.cache_stats
    assert cs["hits"] == 1 and cs["misses"] == 1
    # wing and tip plans never share executables: signatures differ
    tip_sig = Executor(
        EngineConfig(kernel_blocks=SMALL_BLOCKS, backend="xla",
                     num_partitions=4)).plan(g).signature
    assert wd1.plan.signature != tip_sig
    assert wd1.plan.signature[-1] == "wing"


def test_executor_wing_side_v_maps_back():
    """psi is side-symmetric but the transposed run REORDERS edges
    (from_edges canonicalizes by the peeled-side key); the result maps
    back to the graph's canonical edge order."""
    g = GRAPH_CASES["er_dense"]()
    wd = Executor(_api_cfg(side="V")).decompose(g, verify=True)
    np.testing.assert_array_equal(wd.edge_wing, _oracle("er_dense"))


def test_executor_map_rejects_wing():
    g = GRAPH_CASES["fig1"]()
    with pytest.raises(ValueError, match="tip"):
        Executor(_api_cfg()).map([g])


def test_engine_config_rejects_wing_tiled():
    with pytest.raises(ValueError, match="tiled"):
        EngineConfig(workload="wing", representation="tiled")


# --------------------------------------------------------------------- #
# FD pre-peel hoisting: psi/theta invariant in fd_prepeel_levels
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("levels", [1, 2, 4, 8])
def test_tip_theta_invariant_under_prepeel_hoisting(levels):
    """Iterated host pre-peel (levels 2, 3, ... hoisted while the
    device is busy) never changes theta — tip numbers are canonical
    across exact schedules (closes the deferred pre-peel item)."""
    g = GRAPH_CASES["er_small"]()
    tb, _ = bup_oracle(g)
    th, stats = tip_decompose(g, _cfg(fd_prepeel_levels=levels))
    np.testing.assert_array_equal(th, tb)
    if levels > 1:
        assert stats.rho_fd >= 1


def test_tip_prepeel_hoists_more_levels_host_side():
    """More hoisted levels -> fewer device loop dispatches never hurts
    exactness; spot-check that hoisting actually engages (rho_fd counts
    host-hoisted sweeps too, so it is level-count invariant)."""
    g = GRAPH_CASES["er_dense"]()
    tb, _ = bup_oracle(g)
    rhos = {}
    for lv in (1, 4):
        th, stats = tip_decompose(g, _cfg(fd_prepeel_levels=lv))
        np.testing.assert_array_equal(th, tb)
        rhos[lv] = stats.rho_fd
    assert rhos[1] == rhos[4]   # same exact schedule, same sweep count


# --------------------------------------------------------------------- #
# property tests: adversarial degree sequences, tip AND wing parity
# (hypothesis when installed; skipped cleanly otherwise)
# --------------------------------------------------------------------- #
def _skewed_graph(n_u, n_v, shape, seed):
    """Adversarial degree-sequence generator: shapes chosen to defeat
    degree-sort tile concentration and stress the level/range peels."""
    rng = np.random.default_rng(seed)
    if shape == "star":
        # one dominant hub column + a thin fringe
        eu = list(range(n_u)) + list(rng.integers(0, n_u, n_u))
        ev = [0] * n_u + list(rng.integers(1, max(n_v, 2), n_u))
    elif shape == "block":
        # near-complete block embedded in a sparse halo
        bu, bv = max(n_u // 2, 2), max(n_v // 2, 2)
        mask = rng.random((bu, bv)) < 0.9
        eu, ev = [list(x) for x in np.nonzero(mask)]
        eu += list(rng.integers(0, n_u, n_u))
        ev += list(rng.integers(0, n_v, n_u))
    else:  # "skew": Zipf-ish row degrees, anti-sorted columns
        deg = np.maximum((n_v / np.arange(1, n_u + 1)).astype(int), 1)
        eu, ev = [], []
        for u, d in enumerate(deg):
            cols = rng.choice(n_v, size=min(d, n_v), replace=False)
            eu += [u] * len(cols)
            ev += list(cols)
    return BipartiteGraph.from_edges(n_u, n_v, eu, ev)


@settings(max_examples=12, deadline=None)
@given(
    n_u=st.integers(4, 16),
    n_v=st.integers(3, 12),
    shape=st.sampled_from(["star", "block", "skew"]),
    p=st.integers(1, 6),
    dispatch=st.sampled_from(["subset", "graph"]),
    seed=st.integers(0, 10_000),
)
def test_property_engine_parity_adversarial(n_u, n_v, shape, p, dispatch,
                                            seed):
    """Tip AND wing engine paths match their oracles on adversarial
    degree sequences (stars, near-complete blocks, sort-defeating
    skew) in both dispatch modes."""
    g = _skewed_graph(n_u, n_v, shape, seed)
    cfg = _cfg(num_partitions=p, cd_dispatch=dispatch)
    tb, _ = bup_oracle(g)
    th, _ = tip_decompose(g, cfg)
    np.testing.assert_array_equal(th, tb)
    po, _ = wing_bup_oracle(g)
    pr, _ = wing_decompose_engine(g, cfg)
    np.testing.assert_array_equal(pr, po)


@settings(max_examples=12, deadline=None)
@given(
    n_u=st.integers(3, 12),
    n_v=st.integers(3, 10),
    density=st.floats(0.15, 0.6),
    p=st.integers(1, 6),
    seed=st.integers(0, 10_000),
)
def test_property_wing_equals_oracle(n_u, n_v, density, p, seed):
    rng = np.random.default_rng(seed)
    a = rng.random((n_u, n_v)) < density
    eu, ev = np.nonzero(a)
    g = BipartiteGraph.from_edges(n_u, n_v, eu, ev)
    if g.m == 0:
        return
    po, _ = wing_bup_oracle(g)
    pr, _ = wing_decompose(g, num_partitions=p)
    np.testing.assert_array_equal(po, pr)
    pe, _ = wing_decompose_engine(g, _cfg(num_partitions=p))
    np.testing.assert_array_equal(po, pe)


# --------------------------------------------------------------------- #
# subprocess equivalence: both dispatches + both sides in a fresh
# interpreter (mirrors test_tiled.py's dense/tiled equivalence idiom)
# --------------------------------------------------------------------- #
_EQUIV_SCRIPT = r"""
import sys, json
sys.path.insert(0, "src")
import numpy as np
from repro.core.graph import powerlaw_bipartite
from repro.core.wing import wing_bup_oracle
from repro.core.receipt import ReceiptConfig
from repro.core.engine import wing_decompose_engine

g = powerlaw_bipartite(96, 64, 700, seed=2)
oracle = wing_bup_oracle(g)[0]
cfg = dict(num_partitions=3, kernel_blocks=(8, 8, 8), backend="xla")
subset, _ = wing_decompose_engine(
    g, ReceiptConfig(cd_dispatch="subset", **cfg))
graph, st = wing_decompose_engine(
    g, ReceiptConfig(cd_dispatch="graph", **cfg))
side_v, _ = wing_decompose_engine(
    g, ReceiptConfig(cd_dispatch="subset", **cfg), side="V")
print(json.dumps({
    "subset_ok": bool((subset == oracle).all()),
    "graph_ok": bool((graph == oracle).all()),
    "side_v_ok": bool((side_v == oracle).all()),
    "max_psi": int(oracle.max()),
    "graph_round_trips": int(st.host_round_trips),
}))
"""


@pytest.mark.slow
def test_subprocess_wing_equivalence():
    res = subprocess.run(
        [sys.executable, "-c", _EQUIV_SCRIPT],
        capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stderr[-2000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    assert out["subset_ok"] and out["graph_ok"] and out["side_v_ok"]
    assert out["max_psi"] > 0
    assert out["graph_round_trips"] <= 4
