"""The repro.api plan/compile/execute service layer (PR 5 tentpole):
EngineConfig validation + serialization, Planner/ExecutionPlan fields,
the Executor's cross-graph executable cache (trace-counter-asserted),
and multi-graph batched decomposition (Executor.map)."""
import dataclasses

import numpy as np
import pytest

from repro.api import (
    EngineConfig,
    Executor,
    Planner,
    TipDecomposition,
    decompose,
)
from repro.core.graph import BipartiteGraph, powerlaw_bipartite
from repro.core.peeling import bup_oracle
from repro.core.receipt import ReceiptConfig, tip_decompose

from conftest import GRAPH_CASES

SMALL_BLOCKS = (8, 8, 8)


def _cfg(**kw):
    base = dict(num_partitions=6, kernel_blocks=SMALL_BLOCKS, backend="xla")
    base.update(kw)
    return EngineConfig(**base)


def _permuted_copy(g: BipartiteGraph, seed: int) -> BipartiteGraph:
    """An isomorphic copy (rows and cols relabeled): same bucketed shape,
    same support/wedge multisets — the executable cache's home turf."""
    rng = np.random.default_rng(seed)
    pu = rng.permutation(g.n_u)
    pv = rng.permutation(g.n_v)
    return BipartiteGraph.from_edges(g.n_u, g.n_v, pu[g.edges_u],
                                     pv[g.edges_v])


# --------------------------------------------------------------------- #
# EngineConfig: strict validation + serialization round trip
# --------------------------------------------------------------------- #
def test_engine_config_roundtrip():
    cfg = _cfg(num_partitions=12, side="V", cd_dispatch="graph",
               fd_update_mode="kernel", peel_width=32)
    assert EngineConfig.from_dict(cfg.to_dict()) == cfg
    # to_dict is JSON-able (tuples become lists)
    import json

    assert json.loads(json.dumps(cfg.to_dict())) == cfg.to_dict()


def test_engine_config_rejects_unknown_keys_with_hint():
    d = _cfg().to_dict()
    d["num_partition"] = 4                       # typo'd knob
    with pytest.raises(ValueError, match="num_partitions"):
        EngineConfig.from_dict(d)
    with pytest.raises(ValueError, match="unknown key"):
        EngineConfig.from_dict({"definitely_not_a_knob": 1})


@pytest.mark.parametrize("bad", [
    dict(side="W"),
    dict(dtype="float64"),
    dict(backend="palas"),                       # typo: actionable error
    dict(fd_mode="Level"),
    dict(cd_dispatch="Graph"),
    dict(num_partitions=0),
    dict(max_sweeps=0),
    dict(peel_width=0),
    dict(dgm_row_threshold=0.0),
    dict(fd_update_mode="fast"),
    dict(kernel_blocks=(8, 8)),
])
def test_engine_config_rejects_bad_values(bad):
    with pytest.raises(ValueError):
        _cfg(**bad)


def test_engine_config_rejects_conflicting_knobs():
    """Cross-knob conflicts that RUN but silently diverge from the
    benched configuration are service-layer errors (the raw
    ReceiptConfig keeps permitting them for A/B tests)."""
    with pytest.raises(ValueError, match="use_dgm"):
        _cfg(cd_dispatch="graph", use_dgm=False)
    with pytest.raises(ValueError, match="device_loop"):
        _cfg(cd_dispatch="graph", device_loop=False)
    # the engine-layer config allows the A/B combination
    ReceiptConfig(cd_dispatch="graph", use_dgm=False)


def test_receipt_config_validates_at_construction():
    """The legacy config's validation gaps are closed: a typo'd backend
    used to silently route to the compiled pallas kernel."""
    with pytest.raises(ValueError, match="backend"):
        ReceiptConfig(backend="palas")
    with pytest.raises(ValueError, match="dgm_row_threshold"):
        ReceiptConfig(dgm_row_threshold=1.5)
    with pytest.raises(ValueError, match="square"):
        ReceiptConfig(backend="pallas_sparse", kernel_blocks=(8, 16, 8))


# --------------------------------------------------------------------- #
# Planner / ExecutionPlan
# --------------------------------------------------------------------- #
def test_plan_surfaces_execution_structure():
    g = GRAPH_CASES["powerlaw"]()
    plan = Planner(_cfg(num_partitions=8)).plan(g)
    assert plan.rows_pad >= g.n_u and plan.rows_pad % 8 == 0
    assert plan.cols_pad % 8 == 0
    assert plan.backend == "xla" and "oracle" in plan.kernel_route
    assert plan.cd_dispatch == "subset"
    assert plan.num_partitions == 8
    assert plan.cd_peel_width0 >= 8
    assert plan.fd_mode == "level"
    assert plan.est_fd_groups, "planner must estimate FD shape groups"
    assert all(g_["rows"] % 8 == 0 for g_ in plan.est_fd_groups)
    assert 0.0 <= plan.est_fd_padding_waste < 1.0
    assert plan.padded_bytes > 0
    assert plan.mesh_shards == 0
    assert isinstance(plan.describe(), str) and "CD" in plan.describe()
    d = plan.to_dict()
    assert d["rows_pad"] == plan.rows_pad


def test_plan_signature_keys_on_bucketed_shape_and_config():
    p = Planner(_cfg())
    g1 = powerlaw_bipartite(100, 60, 700, seed=0)
    g2 = powerlaw_bipartite(101, 60, 700, seed=3)     # same buckets
    g3 = powerlaw_bipartite(400, 60, 700, seed=0)     # different bucket
    assert p.plan(g1).signature == p.plan(g2).signature
    assert p.plan(g1).signature != p.plan(g3).signature
    assert p.plan(g1).signature != Planner(
        _cfg(num_partitions=12)).plan(g1).signature


def test_planner_rejects_non_graph_with_ingestion_hint():
    with pytest.raises(ValueError, match="from_edges"):
        Planner(_cfg()).plan(np.zeros((4, 4)))


def test_graph_ingestion_from_dense():
    a = np.zeros((5, 4))
    a[[0, 0, 1, 1, 2], [0, 1, 0, 1, 3]] = 1
    g = BipartiteGraph.from_dense(a)
    assert (g.n_u, g.n_v, g.m) == (5, 4, 5)
    np.testing.assert_array_equal(
        BipartiteGraph.from_dense(a.astype(bool)).edges_u, g.edges_u)
    with pytest.raises(ValueError, match="2-D"):
        BipartiteGraph.from_dense(np.zeros(3))
    with pytest.raises(ValueError, match="0/1"):
        BipartiteGraph.from_dense(np.full((2, 2), 2.0))


# --------------------------------------------------------------------- #
# Executor: decompose + the cross-graph executable cache
# --------------------------------------------------------------------- #
def test_executor_decompose_matches_oracle_and_compat():
    g = GRAPH_CASES["powerlaw"]()
    tb, _ = bup_oracle(g)
    ex = Executor(_cfg())
    td = ex.decompose(g)
    np.testing.assert_array_equal(td.theta, tb)
    t_legacy, _ = tip_decompose(
        g, ReceiptConfig(num_partitions=6, kernel_blocks=SMALL_BLOCKS,
                         backend="xla"))
    np.testing.assert_array_equal(td.theta, t_legacy)
    assert td.stats.num_subsets >= 1
    assert td.plan.measured.runs == 1


def test_executor_cache_hits_and_misses():
    ex = Executor(_cfg())
    g1 = powerlaw_bipartite(100, 60, 700, seed=0)
    ex.decompose(g1)
    assert ex.cache_stats == dict(entries=1, hits=0, misses=1,
                                  quarantined=0, fallback_runs=0)
    ex.decompose(powerlaw_bipartite(100, 60, 700, seed=5))
    assert ex.cache_stats["hits"] == 1
    ex.decompose(powerlaw_bipartite(420, 60, 700, seed=0))  # new bucket
    assert ex.cache_stats["entries"] == 2
    assert ex.cache_stats["misses"] == 2


@pytest.mark.parametrize("dispatch,dgm", [("subset", False),
                                          ("graph", True)])
def test_executor_cache_skips_tracing_on_same_signature(dispatch, dgm):
    """The acceptance claim: decomposing K graphs of the same bucketed
    shape traces the pipeline EXACTLY once.  Isomorphic copies share
    every support/wedge multiset, so with the cache pinning the measured
    peel widths and stack shapes, runs 2..K are pure jit-cache hits —
    the jax tracing counter must stay at zero.  (Host re-induction
    re-buckets data-dependently, so the subset-dispatch case runs with
    use_dgm=False; the graph dispatch compacts on device at fixed
    shapes and keeps DGM on.)"""
    from jax._src import test_util as jtu

    base = powerlaw_bipartite(90, 50, 600, seed=2)
    graphs = [base] + [_permuted_copy(base, s) for s in (1, 2, 3)]
    ex = Executor(_cfg(cd_dispatch=dispatch, use_dgm=dgm,
                       num_partitions=4))
    tb, _ = bup_oracle(base)
    cold = ex.decompose(graphs[0])                 # traces everything
    np.testing.assert_array_equal(cold.theta, tb)
    for g in graphs[1:]:
        with jtu.count_jit_tracing_cache_miss() as misses:
            td = ex.decompose(g)
        assert misses[0] == 0, (
            f"same-signature decompose retraced {misses[0]} function(s)")
        # cached executions stay bit-identical to a cold run
        cold_ref = Executor(_cfg(cd_dispatch=dispatch, use_dgm=dgm,
                                 num_partitions=4)).decompose(g)
        np.testing.assert_array_equal(td.theta, cold_ref.theta)


def test_executor_cache_different_signature_retraces():
    """A different bucketed shape MUST miss (and trace)."""
    from jax._src import test_util as jtu

    ex = Executor(_cfg(num_partitions=4))
    ex.decompose(powerlaw_bipartite(90, 50, 600, seed=2))
    with jtu.count_jit_tracing_cache_miss() as misses:
        ex.decompose(powerlaw_bipartite(400, 220, 2400, seed=2))
    assert misses[0] > 0
    assert ex.cache_stats["entries"] == 2


def test_executor_cache_hit_skips_graph_dispatch_sizing_sync():
    """On a cache hit the graph dispatch reuses the measured peel width
    instead of sizing from a host snapshot: the whole CD phase drops to
    ONE blocking round trip."""
    base = powerlaw_bipartite(90, 50, 600, seed=2)
    ex = Executor(_cfg(cd_dispatch="graph", num_partitions=4))
    first = ex.decompose(base)
    second = ex.decompose(_permuted_copy(base, 7))
    assert first.stats.overflow_fallbacks == 0
    assert second.stats.host_round_trips < first.stats.host_round_trips


# --------------------------------------------------------------------- #
# measured peel widths (the ROADMAP deferred item, PR 5 satellite)
# --------------------------------------------------------------------- #
def test_fd_peel_width_probe_replaces_static_heuristic():
    """FD gather widths are sized from the host support snapshot (level
    multiplicities), not mm/8 — recorded per group in RunStats, with the
    measured max level riding back from the device loop."""
    g = GRAPH_CASES["powerlaw"]()
    td = Executor(_cfg()).decompose(g)
    s = td.stats
    assert s.fd_peel_widths and len(s.fd_peel_widths) == s.fd_groups
    assert len(s.fd_max_levels) == s.fd_groups
    assert all(w >= 8 for w in s.fd_peel_widths)
    # the probe is data-derived: measured levels bound the width choice
    # wherever the mask fallback did not fire
    for w, lvl in zip(s.fd_peel_widths, s.fd_max_levels):
        assert lvl <= w or s.fd_mask_fallbacks > 0


def test_fd_measured_width_feeds_back_through_plan():
    base = powerlaw_bipartite(90, 50, 600, seed=2)
    ex = Executor(_cfg(num_partitions=4, use_dgm=False))
    ex.decompose(base)
    sig = next(iter(ex._entries))
    entry = ex._entries[sig]
    assert entry.cd_peel_width is not None
    assert entry.fd_level_widths, "FD widths must be recorded per shape"
    widths_before = dict(entry.fd_level_widths)
    td2 = ex.decompose(_permuted_copy(base, 11))
    # the second run consumed the recorded widths: every group whose
    # stack shape was seen before reuses the recorded (traced) width
    assert td2.plan.measured.cd_peel_width == entry.cd_peel_width
    for shape, width in widths_before.items():
        assert entry.fd_level_widths[shape] == width


def test_fd_undersized_hint_stays_exact_via_mask_fallback():
    """An absurdly small pinned width forces the on-device mask-form
    fallback — exactness must survive, and the fallback is counted."""
    g = GRAPH_CASES["vhub"]()
    tb, _ = bup_oracle(g)
    td = Executor(_cfg(peel_width=8)).decompose(g)
    np.testing.assert_array_equal(td.theta, tb)


# --------------------------------------------------------------------- #
# Executor.map: multi-graph batched decomposition
# --------------------------------------------------------------------- #
def test_map_bit_identical_to_per_graph_and_fewer_dispatches():
    """The acceptance claim: Executor.map over >= 8 small graphs issues
    FEWER device dispatches than 8 sequential tip_decompose calls while
    producing bit-identical tip numbers."""
    graphs = [powerlaw_bipartite(60, 40, 350, seed=s) for s in range(8)]
    cfg = _cfg(num_partitions=4)
    ex = Executor(cfg)
    tds = ex.map(graphs)
    assert len(tds) == 8
    seq_dispatches = 0
    rcfg = cfg.to_receipt_config()
    for g, td in zip(graphs, tds):
        t_seq, s_seq = tip_decompose(g, rcfg)
        np.testing.assert_array_equal(td.theta, t_seq)
        tb, _ = bup_oracle(g)
        np.testing.assert_array_equal(td.theta, tb)
        seq_dispatches += s_seq.device_loop_calls + s_seq.host_round_trips
    rep = ex.last_map_report
    map_dispatches = (rep["device_loop_calls"] + rep["counting_dispatches"]
                      + rep["host_round_trips"])
    assert map_dispatches < seq_dispatches, (map_dispatches, seq_dispatches)
    assert rep["n_graphs"] == 8 and rep["chunks"] >= 1


def test_map_mixed_shapes_and_sides():
    """Graphs of different buckets group separately; side='V' peels the
    other vertex set per graph."""
    gs = [powerlaw_bipartite(40, 30, 200, seed=s) for s in range(3)]
    gs += [powerlaw_bipartite(150, 80, 900, seed=s) for s in range(2)]
    ex = Executor(_cfg(num_partitions=4))
    tds = ex.map(gs)
    assert ex.last_map_report["groups"] >= 2
    for g, td in zip(gs, tds):
        tb, _ = bup_oracle(g)
        np.testing.assert_array_equal(td.theta, tb)

    exv = Executor(_cfg(side="V"))
    tdv = exv.map(gs[:2])
    for g, td in zip(gs[:2], tdv):
        tbv, _ = bup_oracle(g.transposed())
        np.testing.assert_array_equal(td.theta, tbv)


def test_map_reuses_executables_across_calls():
    """A second fleet of the same bucketed shape runs out of the cache
    (hit-rate reported, no retracing)."""
    from jax._src import test_util as jtu

    mk = lambda seed: [powerlaw_bipartite(60, 40, 350, seed=s)
                       for s in range(seed, seed + 6)]
    ex = Executor(_cfg())
    ex.map(mk(0))
    assert ex.last_map_report["cache_misses"] >= 1
    with jtu.count_jit_tracing_cache_miss() as misses:
        tds = ex.map(mk(20))
    assert misses[0] == 0, "same-shape fleet must not retrace"
    assert ex.last_map_report["cache_hits"] >= 1
    for g, td in zip(mk(20), tds):
        tb, _ = bup_oracle(g)
        np.testing.assert_array_equal(td.theta, tb)


def test_map_edge_cases():
    assert Executor(_cfg()).map([]) == []
    # an edgeless graph has all-zero tips; a tiny dense one is fine too
    g0 = BipartiteGraph.from_edges(5, 4, [], [])
    g1 = GRAPH_CASES["fig1"]()
    ex = Executor(_cfg(num_partitions=2))
    tds = ex.map([g0, g1])
    np.testing.assert_array_equal(tds[0].theta, np.zeros(5, np.int64))
    tb, _ = bup_oracle(g1)
    np.testing.assert_array_equal(tds[1].theta, tb)


def test_map_respects_stack_cell_budget():
    """Oversized fleets split into LPT-balanced chunks."""
    graphs = [powerlaw_bipartite(60, 40, 350, seed=s) for s in range(9)]
    ex = Executor(_cfg(), map_stack_cells=64 * 64 * 2)   # ~2 graphs/chunk
    tds = ex.map(graphs)
    assert ex.last_map_report["chunks"] >= 4
    for g, td in zip(graphs, tds):
        tb, _ = bup_oracle(g)
        np.testing.assert_array_equal(td.theta, tb)


def test_map_rejects_legacy_fd_modes():
    with pytest.raises(ValueError, match="fd_mode"):
        Executor(_cfg(fd_mode="b2")).map([GRAPH_CASES["fig1"]()])


# --------------------------------------------------------------------- #
# TipDecomposition: hierarchy queries
# --------------------------------------------------------------------- #
def test_tip_decomposition_queries():
    g = GRAPH_CASES["fig1"]()
    td = decompose(g, _cfg(num_partitions=2))
    tb, _ = bup_oracle(g)                          # [2, 3, 3, 1]
    np.testing.assert_array_equal(td.theta, tb)
    assert td.n == 4
    assert td.vertex_tip(1) == 3
    assert td.max_theta() == 3
    with pytest.raises(IndexError):
        td.vertex_tip(99)
    sub, members, v_ids = td.subgraph_at(3)
    np.testing.assert_array_equal(members, [1, 2])   # the 3-tip: u2, u3
    assert sub.n_u == 2 and sub.m > 0
    sub_all, members_all, _ = td.subgraph_at(0)
    assert members_all.size == g.n_u


def test_decompose_convenience_accepts_all_config_currencies():
    g = GRAPH_CASES["fig1"]()
    tb, _ = bup_oracle(g)
    for cfg in (None, _cfg(num_partitions=2),
                ReceiptConfig(num_partitions=2, kernel_blocks=SMALL_BLOCKS,
                              backend="xla")):
        td = decompose(g, cfg)
        np.testing.assert_array_equal(td.theta, tb)
    with pytest.raises(ValueError, match="EngineConfig or ReceiptConfig"):
        decompose(g, {"num_partitions": 2})
