"""Per-architecture smoke tests: reduced config, real params, one
forward/train step on CPU asserting output shapes + no NaNs.

(The FULL configs are exercised only via the dry-run — ShapeDtypeStruct,
no allocation.)
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_bundle
from repro.data import synthetic as syn
from repro.models import transformer as tf_lib
from repro.train.train_step import init_train_state

LM_ARCHS = [a for a in ALL_ARCHS if get_bundle(a, reduced=True).family == "lm"]
GNN_ARCHS = [a for a in ALL_ARCHS if get_bundle(a, reduced=True).family == "gnn"]


def _finite(tree) -> bool:
    return all(
        bool(jnp.all(jnp.isfinite(x)))
        for x in jax.tree.leaves(tree)
        if jnp.issubdtype(x.dtype, jnp.floating)
    )


def _run_train(bundle, batch):
    params = bundle.init_params(jax.random.PRNGKey(0))
    state = init_train_state(params, bundle.opt_cfg)
    step = bundle._steps["train"]
    new_state, metrics = jax.jit(step)(state, batch)
    assert _finite(metrics), f"non-finite metrics: {metrics}"
    assert _finite(new_state["params"])
    return new_state, metrics


# --------------------------------------------------------------------- #
# LM
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_train_smoke(arch):
    b = get_bundle(arch, reduced=True)
    batch = syn.lm_train_batch(b.cfg.vocab, batch=4, seq=32, seed=1)
    state, metrics = _run_train(b, batch)
    assert metrics["loss"] > 0


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_prefill_and_decode_smoke(arch):
    b = get_bundle(arch, reduced=True)
    cfg = b.cfg
    params = b.init_params(jax.random.PRNGKey(0))
    toks = syn.lm_train_batch(cfg.vocab, 2, 16, seed=2)["tokens"]
    logits = jax.jit(lambda p, t: tf_lib.lm_prefill(p, t, cfg))(params, toks)
    assert logits.shape == (2, cfg.vocab)
    assert _finite(logits)

    cache = tf_lib.init_cache(cfg, 2, 24)
    dec = jax.jit(lambda p, c, t: tf_lib.lm_decode_step(p, c, t, cfg))
    lg, cache = dec(params, cache, jnp.array([1, 2], jnp.int32))
    lg, cache = dec(params, cache, jnp.array([3, 4], jnp.int32))
    assert lg.shape == (2, cfg.vocab)
    assert int(cache["len"]) == 2
    assert _finite(lg)


def test_decode_matches_prefill_gqa():
    """Integration: token-by-token decode reproduces teacher-forced
    prefill logits (cache path == parallel path)."""
    b = get_bundle("minitron-8b", reduced=True)
    cfg = b.cfg
    params = b.init_params(jax.random.PRNGKey(0))
    toks = syn.lm_train_batch(cfg.vocab, 2, 8, seed=3)["tokens"]

    h, _ = tf_lib.lm_hidden(params, toks, cfg)
    full_logits = tf_lib.lm_logits(params, h, cfg)          # (B, S, V)

    cache = tf_lib.init_cache(cfg, 2, 8)
    dec = jax.jit(lambda p, c, t: tf_lib.lm_decode_step(p, c, t, cfg))
    for t in range(8):
        lg, cache = dec(params, cache, toks[:, t])
        np.testing.assert_allclose(
            np.asarray(lg), np.asarray(full_logits[:, t]), rtol=2e-4, atol=2e-4
        )


def test_decode_matches_prefill_mla():
    """Same equivalence for the weight-absorbed MLA decode path."""
    b = get_bundle("deepseek-v2-236b", reduced=True)
    cfg = b.cfg
    params = b.init_params(jax.random.PRNGKey(0))
    toks = syn.lm_train_batch(cfg.vocab, 2, 6, seed=4)["tokens"]
    h, _ = tf_lib.lm_hidden(params, toks, cfg)
    full_logits = tf_lib.lm_logits(params, h, cfg)
    cache = tf_lib.init_cache(cfg, 2, 6)
    dec = jax.jit(lambda p, c, t: tf_lib.lm_decode_step(p, c, t, cfg))
    for t in range(6):
        lg, cache = dec(params, cache, toks[:, t])
        np.testing.assert_allclose(
            np.asarray(lg), np.asarray(full_logits[:, t]), rtol=5e-4, atol=5e-4
        )


def test_flash_attention_matches_naive():
    from repro.models.attention import flash_attention

    rng = np.random.default_rng(0)
    b, h, hkv, s, d = 2, 4, 2, 64, 16
    q = jnp.asarray(rng.normal(size=(b, h, s, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, hkv, s, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, hkv, s, d)).astype(np.float32))
    out = flash_attention(q, k, v, causal=True, q_block=16, kv_block=16)
    # naive reference
    kr = jnp.repeat(k, h // hkv, axis=1)
    vr = jnp.repeat(v, h // hkv, axis=1)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, kr) / np.sqrt(d)
    mask = np.tril(np.ones((s, s), bool))
    scores = jnp.where(mask[None, None], scores, -1e30)
    want = jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(scores, -1), vr)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-4, atol=1e-5)


def test_moe_dispatch_matches_dense_compute():
    """Index-dispatched MoE == explicit per-token expert loop (no drops)."""
    from repro.models.moe import init_moe, moe_forward, route

    key = jax.random.PRNGKey(0)
    d, f, ne, k = 8, 16, 4, 2
    p = init_moe(key, d, f, ne, n_shared=0)
    x = jax.random.normal(key, (2, 8, d))
    out, _ = moe_forward(p, x, top_k=k, capacity_factor=8.0)  # huge capacity: no drops
    # reference: dense loop
    x2 = x.reshape(-1, d)
    idx, gates, _ = route(p, x2, top_k=k)
    want = jnp.zeros_like(x2)
    for t in range(x2.shape[0]):
        acc = jnp.zeros((d,))
        for j in range(k):
            e = idx[t, j]
            g = jax.nn.silu(x2[t] @ p["gate"][e]) * (x2[t] @ p["up"][e])
            acc += gates[t, j] * (g @ p["down"][e])
        want = want.at[t].set(acc)
    np.testing.assert_allclose(
        np.asarray(out.reshape(-1, d)), np.asarray(want), rtol=1e-4, atol=1e-5
    )


# --------------------------------------------------------------------- #
# GNN
# --------------------------------------------------------------------- #
def _gnn_smoke_batch(arch, cfg):
    if arch == "meshgraphnet":
        return syn.meshgraphnet_batch(cfg, n_nodes=40, n_edges=120, seed=0)
    if arch == "graphsage-reddit":
        return syn.graphsage_full_batch(cfg, n_nodes=50, n_edges=200, seed=0)
    if arch == "dimenet":
        return syn.dimenet_batch(cfg, n_nodes=24, n_edges=60, n_graphs=4,
                                 triplet_fanout=6, seed=0)
    if arch == "graphcast":
        return syn.graphcast_batch(cfg, n_grid=30, seed=0)
    raise KeyError(arch)


@pytest.mark.parametrize("arch", GNN_ARCHS)
def test_gnn_train_smoke(arch):
    b = get_bundle(arch, reduced=True)
    batch = _gnn_smoke_batch(arch, b.cfg)
    _run_train(b, batch)


def test_graphsage_sampled_smoke():
    b = get_bundle("graphsage-reddit", reduced=True)
    blocks = syn.graphsage_sampled_batch(
        b.cfg, batch_nodes=16, fanouts=b.cfg.sample_sizes,
        n_nodes=200, n_edges=900, seed=0,
    )
    params = b.init_params(jax.random.PRNGKey(0))
    state = init_train_state(params, b.opt_cfg)
    step = b._steps["train_sampled"]
    new_state, metrics = jax.jit(step)(state, blocks)
    assert _finite(metrics)


def test_sampler_respects_graph_structure():
    """Sampled neighbours are actual graph neighbours."""
    from repro.models.sampler import build_nbr_table, sample_block

    rng = np.random.default_rng(0)
    snd, rcv = syn.random_graph(30, 100, seed=1)
    table, deg = build_nbr_table(snd, rcv, 30, max_deg=16)
    adj = {(int(s)): set() for s in range(30)}
    for s, r in zip(snd, rcv):
        if len(adj[int(s)]) < 16:
            adj[int(s)].add(int(r))
    nodes = jnp.arange(30, dtype=jnp.int32)
    nb, _ = sample_block(jax.random.PRNGKey(0), jnp.asarray(table),
                         jnp.asarray(deg), nodes, fanout=5)
    nb = np.asarray(nb)
    for i in range(30):
        for x in nb[i]:
            if x >= 0:
                assert int(x) in adj[i]
            else:
                assert deg[i] == 0


# --------------------------------------------------------------------- #
# recsys
# --------------------------------------------------------------------- #
def test_recsys_train_smoke():
    b = get_bundle("two-tower-retrieval", reduced=True)
    batch = syn.recsys_batch(b.cfg, batch=16, seed=0)
    _run_train(b, batch)


def test_recsys_serve_and_retrieval_smoke():
    b = get_bundle("two-tower-retrieval", reduced=True)
    params = b.init_params(jax.random.PRNGKey(0))
    batch = syn.recsys_batch(b.cfg, batch=8, seed=1, with_logq=False)
    scores = jax.jit(b._steps["serve"])(params, batch)
    assert scores.shape == (8,)
    assert _finite(scores)

    cand = jax.random.normal(jax.random.PRNGKey(2), (100, b.cfg.tower_mlp[-1]))
    vals, idx = jax.jit(b._steps["retrieval"])(
        params, {"user_ids": batch["user_ids"][:1], "cand_emb": cand}
    )
    assert vals.shape == (1, 100) or vals.shape[1] <= 100


def test_embedding_bag_matches_loop():
    from repro.models.recsys import embedding_bag

    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.normal(size=(20, 4)).astype(np.float32))
    ids = jnp.asarray(np.array([[1, 3, -1], [0, -1, -1], [5, 5, 5]], np.int32))
    out = embedding_bag(table, ids, mode="mean")
    want = np.stack([
        (table[1] + table[3]) / 2,
        table[0],
        table[5],
    ])
    np.testing.assert_allclose(np.asarray(out), want, rtol=1e-6)
