"""Benchmark-regression gate (scripts/bench_gate.py) unit tests.

The gate must PASS on the shipped BENCH_receipt.json compared against
itself (CI sanity: the checked-in numbers satisfy their own invariants)
and FAIL on seeded synthetic regressions — an inflated round-trip count,
a lost DGM wedge parity, a drifted deterministic counter.  Pure JSON
manipulation: no engine runs, safe for the quick suite.
"""
import copy
import importlib.util
import json
import pathlib

ROOT = pathlib.Path(__file__).resolve().parent.parent

_spec = importlib.util.spec_from_file_location(
    "bench_gate", ROOT / "scripts" / "bench_gate.py")
bench_gate = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(bench_gate)


def _baseline() -> dict:
    return json.loads((ROOT / "BENCH_receipt.json").read_text())


def test_gate_passes_on_shipped_numbers():
    base = _baseline()
    assert bench_gate.gate(base, base, rel_tol=0.10) == []


def test_gate_passes_on_quick_subset_of_graphs():
    """A --quick fresh run (first graph only) gates against the matching
    baseline entry; the baseline-only graphs are skipped."""
    base = _baseline()
    fresh = copy.deepcopy(base)
    fresh["graphs"] = fresh["graphs"][:1]
    assert bench_gate.gate(fresh, base, rel_tol=0.10) == []


def test_gate_fails_on_inflated_round_trips():
    """The seeded regression of the acceptance criterion: the O(1)
    single-dispatch round-trip count silently inflating."""
    base = _baseline()
    fresh = copy.deepcopy(base)
    g = fresh["graphs"][0]
    g["derived"]["cd_rt_graph_total"] = 40          # ~ one RT per subset
    g["cd_phase_round_trips"]["graph"]["host_round_trips"] = 40
    errors = bench_gate.gate(fresh, base, rel_tol=0.10)
    assert any("cd_rt_graph_total inflated" in e for e in errors), errors


def test_gate_fails_on_lost_wedge_parity():
    base = _baseline()
    fresh = copy.deepcopy(base)
    fresh["graphs"][0]["derived"]["cd_graph_wedge_ratio"] = 1.5
    errors = bench_gate.gate(fresh, base, rel_tol=0.10)
    assert any("wedge parity" in e for e in errors), errors


def test_gate_fails_on_counter_drift():
    base = _baseline()
    fresh = copy.deepcopy(base)
    g = fresh["graphs"][0]["cd_phase_round_trips"]["graph"]
    g["wedges_cd"] = int(g["wedges_cd"] * 2 + 100)
    errors = bench_gate.gate(fresh, base, rel_tol=0.10)
    assert any("wedges_cd drifted" in e for e in errors), errors


def test_gate_fails_on_disjoint_graphs():
    base = _baseline()
    fresh = copy.deepcopy(base)
    for g in fresh["graphs"]:
        g["name"] = g["name"] + "_renamed"
    errors = bench_gate.gate(fresh, base, rel_tol=0.10)
    assert errors and "no common graphs" in errors[0]


def test_gate_tolerates_overflow_surcharge():
    """Overflow replays legitimately add bounded RTs; the gate must not
    flag an environment-dependent overflow as a regression."""
    base = _baseline()
    fresh = copy.deepcopy(base)
    g = fresh["graphs"][0]
    g["cd_phase_round_trips"]["graph"]["overflow_fallbacks"] = 1
    g["derived"]["cd_rt_graph_total"] = (
        g["derived"]["cd_rt_graph_total"] + bench_gate.OVF_RT_SURCHARGE)
    assert bench_gate.gate(fresh, base, rel_tol=0.10) == []


def test_gate_fails_on_lost_map_dispatch_reduction():
    """The PR 5 seeded regression: Executor.map degenerating into one
    dispatch per graph."""
    base = _baseline()
    fresh = copy.deepcopy(base)
    fresh["executor_map"]["dispatch_reduction"] = 1.1
    errors = bench_gate.gate(fresh, base, rel_tol=0.10)
    assert any("dispatch_reduction" in e for e in errors), errors


def test_gate_fails_on_cold_warm_fleet():
    base = _baseline()
    fresh = copy.deepcopy(base)
    fresh["executor_map"]["warm_cache_hit_rate"] = 0.5
    errors = bench_gate.gate(fresh, base, rel_tol=0.10)
    assert any("warm_cache_hit_rate" in e for e in errors), errors


def test_gate_fails_on_guardrail_overhead():
    """The PR 6 seeded regression: the hardened runtime's guardrails
    slowing the warm map path beyond the 5% acceptance budget."""
    base = _baseline()
    fresh = copy.deepcopy(base)
    fresh["executor_map"]["bare_wall_warm_s"] = 1.0
    fresh["executor_map"]["guarded_wall_warm_s"] = 1.2
    fresh["executor_map"]["guardrail_overhead"] = 0.2
    errors = bench_gate.gate(fresh, base, rel_tol=0.10)
    assert any("guardrail_overhead" in e for e in errors), errors


def test_gate_guardrail_overhead_absolute_slack():
    """Sub-millisecond deltas are noise even at a large ratio — the
    absolute slack must swallow them (and baselines without the PR 6
    keys must not trip the gate at all)."""
    base = _baseline()
    fresh = copy.deepcopy(base)
    fresh["executor_map"]["bare_wall_warm_s"] = 0.001
    fresh["executor_map"]["guarded_wall_warm_s"] = 0.002
    fresh["executor_map"]["guardrail_overhead"] = 1.0
    assert bench_gate.gate(fresh, base, rel_tol=0.10) == []
    fresh["executor_map"].pop("guardrail_overhead")
    fresh["executor_map"].pop("guarded_wall_warm_s")
    fresh["executor_map"].pop("bare_wall_warm_s")
    assert bench_gate.gate(fresh, base, rel_tol=0.10) == []


def test_gate_fails_on_dropped_map_section():
    base = _baseline()
    fresh = copy.deepcopy(base)
    del fresh["executor_map"]
    errors = bench_gate.gate(fresh, base, rel_tol=0.10)
    assert any("executor_map section missing" in e for e in errors), errors


def test_gate_cli_roundtrip(tmp_path):
    """End-to-end through main(): exit 0 on shipped numbers, exit 1 on
    the seeded round-trip regression."""
    base = _baseline()
    good = tmp_path / "good.json"
    good.write_text(json.dumps(base))
    assert bench_gate.main(["--fresh", str(good)]) == 0

    bad = copy.deepcopy(base)
    bad["graphs"][0]["derived"]["cd_rt_graph_total"] = 99
    bad_p = tmp_path / "bad.json"
    bad_p.write_text(json.dumps(bad))
    assert bench_gate.main(["--fresh", str(bad_p)]) == 1
