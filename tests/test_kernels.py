"""Pallas kernel sweeps: interpret-mode kernel body vs the pure-jnp oracle.

Every (shape x block x dtype x mask) combination asserts allclose (exact,
atol=0) against kernels/ref.py.
"""
import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ref
from repro.kernels.butterfly import butterfly_support_pallas
from repro.kernels.ops import butterfly_support, butterfly_update


def _rand_adj(n_u, n_v, density, seed):
    rng = np.random.default_rng(seed)
    return (rng.random((n_u, n_v)) < density).astype(np.float32)


@pytest.mark.parametrize("blocks", [(8, 8, 8), (8, 16, 8), (16, 8, 32)])
@pytest.mark.parametrize(
    "shape", [(8, 8), (16, 32), (32, 16), (64, 64), (32, 128)]
)
@pytest.mark.parametrize("density", [0.0, 0.2, 0.9])
def test_kernel_counting_sweep(blocks, shape, density):
    bi, bj, bk = blocks
    n_u, n_v = shape
    if n_u % bi or n_u % bj or n_v % bk:
        pytest.skip("shape not divisible by blocks")
    a = _rand_adj(n_u, n_v, density, seed=n_u * n_v)
    s = (np.random.default_rng(0).random(n_u) < 0.7).astype(np.float32)
    want = np.asarray(ref.butterfly_support_ref(jnp.asarray(a), jnp.asarray(s)))
    ids = jnp.arange(n_u, dtype=jnp.int32)
    got = np.asarray(
        butterfly_support_pallas(
            jnp.asarray(a), jnp.asarray(a), jnp.asarray(s), ids, ids,
            blocks=blocks, interpret=True,
        )
    )
    np.testing.assert_allclose(got, want, rtol=0, atol=0)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_dtype_cast(dtype):
    """Kernel casts inputs to f32 internally; bf16 0/1 inputs stay exact."""
    a = _rand_adj(16, 32, 0.3, seed=1).astype(dtype)
    s = jnp.ones(16, dtype)
    ids = jnp.arange(16, dtype=jnp.int32)
    want = np.asarray(
        ref.butterfly_support_ref(jnp.asarray(a, jnp.float32), jnp.ones(16))
    )
    got = np.asarray(
        butterfly_support_pallas(
            jnp.asarray(a), jnp.asarray(a), s, ids, ids,
            blocks=(8, 8, 8), interpret=True,
        )
    )
    np.testing.assert_allclose(got, want, rtol=0, atol=0)


def test_kernel_gathered_update_self_pair_mask():
    """Gathered peel rows must not count self-pairs (ids equality mask)."""
    a = _rand_adj(32, 16, 0.4, seed=2)
    peel_rows = np.array([3, 7, 7, 11, 0, 0, 0, 0], dtype=np.int32)  # padded
    valid = np.array([1, 1, 0, 1, 0, 0, 0, 0], dtype=np.float32)
    a_peel = a[peel_rows] * valid[:, None]
    ids = jnp.arange(32, dtype=jnp.int32)
    got = np.asarray(
        butterfly_update(
            jnp.asarray(a), jnp.asarray(a_peel), jnp.asarray(valid),
            ids, jnp.asarray(peel_rows),
            backend="interpret", blocks=(8, 8, 8),
        )
    )
    # oracle: delta[i] = sum_{u in {3,7,11}, u != i} C(W[i,u], 2)
    w = a @ a.T
    b2 = w * (w - 1) / 2
    want = np.zeros(32)
    for u in (3, 7, 11):
        want += np.where(np.arange(32) == u, 0.0, b2[:, u])
    np.testing.assert_allclose(got, want, rtol=0, atol=0)


def test_ops_xla_backend_matches_interpret():
    a = jnp.asarray(_rand_adj(24, 24, 0.3, seed=3))
    s = jnp.asarray((np.random.default_rng(1).random(24) < 0.5).astype(np.float32))
    x = np.asarray(butterfly_support(a, s, backend="xla"))
    i = np.asarray(butterfly_support(a, s, backend="interpret", blocks=(8, 8, 8)))
    np.testing.assert_allclose(x, i, rtol=0, atol=0)


@settings(max_examples=20, deadline=None)
@given(
    n_u=st.sampled_from([8, 16, 24]),
    n_v=st.sampled_from([8, 16, 40]),
    density=st.floats(0.0, 1.0),
    seed=st.integers(0, 10_000),
)
def test_property_kernel_exactness(n_u, n_v, density, seed):
    a = _rand_adj(n_u, n_v, density, seed)
    rng = np.random.default_rng(seed + 1)
    s = (rng.random(n_u) < 0.5).astype(np.float32)
    want = np.asarray(ref.butterfly_support_ref(jnp.asarray(a), jnp.asarray(s)))
    got = np.asarray(
        butterfly_support(
            jnp.asarray(a), jnp.asarray(s),
            backend="interpret", blocks=(8, 8, 8),
        )
    )
    np.testing.assert_allclose(got, want, rtol=0, atol=0)


def test_counting_paths_agree():
    """dense kernel path == segment (scatter-reduce) path == numpy oracle."""
    from repro.core.counting import (
        butterfly_counts_dense,
        butterfly_counts_numpy,
        butterfly_counts_segment,
        wedge_pair_table,
    )
    from repro.core.graph import random_bipartite

    g = random_bipartite(60, 45, 0.2, seed=7)
    want = butterfly_counts_numpy(g)
    a = jnp.asarray(g.dense())
    dense = np.asarray(butterfly_counts_dense(a, backend="xla"))[: g.n_u]
    us, ups = wedge_pair_table(g)
    seg = np.asarray(
        butterfly_counts_segment(jnp.asarray(us), jnp.asarray(ups), g.n_u)
    )
    np.testing.assert_allclose(dense, want, rtol=0, atol=0)
    np.testing.assert_allclose(seg, want, rtol=0, atol=0)


@pytest.mark.parametrize("blocks", [(8, 8, 8), (16, 16, 16)])
@pytest.mark.parametrize("seed", [0, 3])
def test_sparse_kernel_staircase_skip_exact(blocks, seed):
    """Block-sparse variant (degree-sort stripe skip) stays exact."""
    from repro.core.graph import powerlaw_bipartite
    from repro.kernels.butterfly_sparse import (
        butterfly_support_pallas_sparse, column_extents,
    )

    bi, bj, bk = blocks
    g = powerlaw_bipartite(100, 60, 700, seed=seed).relabel_by_degree()
    a = g.dense(pad_u=bi, pad_v=bk)
    kmax = column_extents(a, bi, bk)
    rng = np.random.default_rng(seed)
    s = jnp.asarray((rng.random(a.shape[0]) < 0.6).astype(np.float32))
    want = np.asarray(ref.butterfly_support_ref(jnp.asarray(a), s))
    got = np.asarray(butterfly_support_pallas_sparse(
        jnp.asarray(a), s, jnp.asarray(kmax), blocks=blocks, interpret=True))
    np.testing.assert_allclose(got, want, rtol=0, atol=0)


@pytest.mark.parametrize("seed", [0, 5])
def test_sparse_gathered_update_matches_dense(seed):
    """Gathered-B update form (the CD peel update) of the staircase
    kernel == dense kernel == jnp oracle, incl. padding rows and
    self-pair masking."""
    from repro.core.graph import powerlaw_bipartite
    from repro.kernels.butterfly_sparse import (
        butterfly_update_pallas_sparse, column_extents,
        gathered_tile_extents, row_extents,
    )

    bi, bj, bk = 8, 8, 8
    g = powerlaw_bipartite(80, 50, 600, seed=seed).relabel_by_degree()
    a = g.dense(pad_u=bi, pad_v=bk)
    n_u = a.shape[0]
    rng = np.random.default_rng(seed)
    n_peel = int(rng.integers(1, 20))
    n_pad = ((n_peel + bj - 1) // bj) * bj
    rows = np.zeros(n_pad, np.int32)
    rows[:n_peel] = rng.choice(g.n_u, size=n_peel, replace=False)
    valid = (np.arange(n_pad) < n_peel)
    a_peel = a[rows] * valid[:, None].astype(np.float32)

    kmax_a = jnp.asarray(column_extents(a, bi, bk))
    row_ext = jnp.asarray(row_extents(a, bk))
    kmax_b = gathered_tile_extents(
        row_ext, jnp.asarray(rows), jnp.asarray(valid), bj
    )
    ids = jnp.arange(n_u, dtype=jnp.int32)
    got = np.asarray(butterfly_update_pallas_sparse(
        jnp.asarray(a), jnp.asarray(a_peel),
        jnp.asarray(valid.astype(np.float32)), ids, jnp.asarray(rows),
        kmax_a, kmax_b, blocks=(bi, bj, bk), interpret=True,
    ))
    want = np.asarray(butterfly_support_pallas(
        jnp.asarray(a), jnp.asarray(a_peel),
        jnp.asarray(valid.astype(np.float32)), ids, jnp.asarray(rows),
        blocks=(bi, bj, bk), interpret=True,
    ))
    oracle = np.asarray(butterfly_update(
        jnp.asarray(a), jnp.asarray(a_peel),
        jnp.asarray(valid.astype(np.float32)), ids, jnp.asarray(rows),
        backend="xla",
    ))
    np.testing.assert_allclose(got, want, rtol=0, atol=0)
    np.testing.assert_allclose(got, oracle, rtol=0, atol=0)


def test_sparse_update_via_ops_backend():
    """ops.butterfly_update routes backend="interpret_sparse" (and the
    conservative no-metadata fallback) to the staircase kernel."""
    a = _rand_adj(16, 16, 0.4, seed=9)
    s = jnp.ones(16, jnp.float32)
    ids = jnp.arange(16, dtype=jnp.int32)
    want = np.asarray(butterfly_update(
        jnp.asarray(a), jnp.asarray(a), s, ids, ids, backend="xla"))
    got = np.asarray(butterfly_update(
        jnp.asarray(a), jnp.asarray(a), s, ids, ids,
        backend="interpret_sparse", blocks=(8, 8, 8)))
    np.testing.assert_allclose(got, want, rtol=0, atol=0)


def _rand_batched(g_n, m, w, c, seed):
    rng = np.random.default_rng(seed)
    a = (rng.random((g_n, m, c)) < 0.3).astype(np.float32)
    rows = rng.integers(0, m, size=(g_n, w)).astype(np.int32)
    valid = (np.arange(w)[None, :]
             < rng.integers(1, w + 1, size=(g_n, 1))).astype(np.float32)
    a_peel = np.take_along_axis(a, rows[:, :, None], axis=1) * valid[:, :, None]
    ids = np.broadcast_to(
        np.arange(m, dtype=np.int32)[None, :], (g_n, m)).copy()
    return a, a_peel, rows, valid, ids


@pytest.mark.parametrize("backend", ["xla", "interpret", "interpret_sparse"])
@pytest.mark.parametrize("shape", [(3, 16, 8, 24), (2, 8, 8, 8), (5, 24, 16, 40)])
def test_batched_update_matches_per_group_kernel(backend, shape):
    """The grouped entry point (FD level-peel dispatch) == a loop of
    single-group kernel calls, for every backend family."""
    from repro.kernels.ops import butterfly_update_batched

    g_n, m, w, c = shape
    a, a_peel, rows, valid, ids = _rand_batched(g_n, m, w, c, seed=m * c)
    want = np.stack([
        np.asarray(butterfly_update(
            jnp.asarray(a[g]), jnp.asarray(a_peel[g]), jnp.asarray(valid[g]),
            jnp.asarray(ids[g]), jnp.asarray(rows[g]), backend="xla"))
        for g in range(g_n)
    ])
    got = np.asarray(butterfly_update_batched(
        jnp.asarray(a), jnp.asarray(a_peel), jnp.asarray(valid),
        jnp.asarray(ids), jnp.asarray(rows),
        backend=backend, blocks=(8, 8, 8)))
    np.testing.assert_allclose(got, want, rtol=0, atol=0)


def test_batched_sparse_per_group_extents_exact():
    """Batched staircase kernel with REAL per-group extents (each stacked
    subset has its own staircase) == the conservative full-extent run."""
    from repro.kernels.butterfly_sparse import (
        batched_gathered_tile_extents, batched_row_extents,
    )
    from repro.kernels.ops import butterfly_update_batched

    g_n, m, w, c = 4, 16, 8, 32
    a, a_peel, rows, valid, ids = _rand_batched(g_n, m, w, c, seed=11)
    # concentrate nonzeros leftward in some groups (staircase regime)
    a[1, :, c // 2:] = 0.0
    a[3, :, c // 4:] = 0.0
    a_peel = np.take_along_axis(a, rows[:, :, None], axis=1) * valid[:, :, None]
    rext = batched_row_extents(a, 8)
    kmax_a = rext.reshape(g_n, -1, 8).max(axis=2)
    kb = batched_gathered_tile_extents(
        jnp.asarray(rext), jnp.asarray(rows), jnp.asarray(valid), 8)
    want = np.asarray(butterfly_update_batched(
        jnp.asarray(a), jnp.asarray(a_peel), jnp.asarray(valid),
        jnp.asarray(ids), jnp.asarray(rows), backend="xla"))
    got = np.asarray(butterfly_update_batched(
        jnp.asarray(a), jnp.asarray(a_peel), jnp.asarray(valid),
        jnp.asarray(ids), jnp.asarray(rows), backend="interpret_sparse",
        blocks=(8, 8, 8), kmax_a=jnp.asarray(kmax_a), kmax_b=kb))
    np.testing.assert_allclose(got, want, rtol=0, atol=0)
    # some stripes were actually skippable
    assert int(kmax_a.min()) < a.shape[2] // 8


def test_batched_row_extents_match_single():
    from repro.kernels.butterfly_sparse import batched_row_extents, row_extents

    rng = np.random.default_rng(3)
    a = (rng.random((3, 24, 32)) < 0.2).astype(np.float32)
    got = batched_row_extents(a, 8)
    want = np.stack([row_extents(a[g], 8) for g in range(3)])
    np.testing.assert_array_equal(got, want)


def test_row_extents_consistent_with_column_extents():
    from repro.core.graph import powerlaw_bipartite
    from repro.kernels.butterfly_sparse import column_extents, row_extents

    g = powerlaw_bipartite(100, 60, 700, seed=2).relabel_by_degree()
    a = g.dense(pad_u=8, pad_v=8)
    kmax = column_extents(a, 8, 8)
    rext = row_extents(a, 8)
    # tile extent == max over its rows' extents
    np.testing.assert_array_equal(kmax, rext.reshape(-1, 8).max(axis=1))


def test_sparse_kernel_skips_something_on_powerlaw():
    from repro.core.graph import powerlaw_bipartite
    from repro.kernels.butterfly_sparse import column_extents

    g = powerlaw_bipartite(300, 200, 2500, seed=1).relabel_by_degree()
    a = g.dense(pad_u=16, pad_v=16)
    kmax = column_extents(a, 16, 16)
    n_i, n_k = a.shape[0] // 16, a.shape[1] // 16
    skipped = sum(
        max(0, n_k - min(kmax[i], kmax[j]))
        for i in range(n_i) for j in range(n_i)
    )
    assert skipped / (n_i * n_i * n_k) > 0.15
