"""Hardened decompose runtime (PR 6 tentpole): the structured error
taxonomy, the deterministic fault-injection harness, graceful
degradation (backend fallback chain, quarantine, admission control,
bounded overflow replay), fleet isolation in ``Executor.map``, verify
mode, and the degenerate-graph battery."""
import numpy as np
import pytest

from repro.api import (
    EngineConfig,
    Executor,
    decompose,
    verify_tip_decomposition,
)
from repro.api.errors import (
    FleetPartialFailure,
    GraphValidationError,
    KernelBackendError,
    PlanInfeasibleError,
    ReceiptError,
    VerificationError,
)
from repro.api.faults import FaultInjector, FaultSpec, fault_point, inject
from repro.core.graph import BipartiteGraph, random_bipartite
from repro.core.peeling import bup_oracle

from conftest import GRAPH_CASES

SMALL_BLOCKS = (8, 8, 8)


def _cfg(**kw):
    base = dict(num_partitions=3, kernel_blocks=SMALL_BLOCKS, backend="xla")
    base.update(kw)
    return EngineConfig(**base)


def _er(nu, nv, ne, seed):
    rng = np.random.default_rng(seed)
    return BipartiteGraph.from_edges(
        nu, nv, rng.integers(0, nu, ne), rng.integers(0, nv, ne))


# --------------------------------------------------------------------- #
# satellite 1: from_dense / validate ingestion battery
# --------------------------------------------------------------------- #
class TestGraphValidation:
    def test_from_dense_rejects_nan_and_inf(self):
        a = np.ones((4, 3))
        a[1, 2] = np.nan
        with pytest.raises(GraphValidationError, match="non-finite"):
            BipartiteGraph.from_dense(a)
        a[1, 2] = np.inf
        with pytest.raises(GraphValidationError, match="non-finite"):
            BipartiteGraph.from_dense(a)
        # binarize is NOT an escape hatch for non-finite input
        with pytest.raises(GraphValidationError, match="binarize"):
            BipartiteGraph.from_dense(a, binarize=True)

    def test_from_dense_rejects_negative_and_weighted(self):
        a = np.zeros((4, 3))
        a[0, 0] = -1.0
        with pytest.raises(GraphValidationError, match="0/1"):
            BipartiteGraph.from_dense(a)
        a[0, 0] = 2.5
        with pytest.raises(GraphValidationError, match="binarize"):
            BipartiteGraph.from_dense(a)

    def test_from_dense_binarize_escape_hatch(self):
        a = np.zeros((4, 3))
        a[0, 0] = 2.5
        a[2, 1] = 7.0
        g = BipartiteGraph.from_dense(a, binarize=True)
        assert g.edges_u.size == 2
        assert sorted(zip(g.edges_u.tolist(), g.edges_v.tolist())) == \
            [(0, 0), (2, 1)]

    def test_from_dense_rejects_zero_size_and_wrong_rank(self):
        with pytest.raises(GraphValidationError, match="zero-size"):
            BipartiteGraph.from_dense(np.zeros((0, 5)))
        with pytest.raises(GraphValidationError, match="2-D"):
            BipartiteGraph.from_dense(np.zeros((2, 2, 2)))

    def test_validation_errors_are_valueerrors(self):
        # pre-hardening handlers caught ValueError; keep them working
        with pytest.raises(ValueError):
            BipartiteGraph.from_dense(np.full((2, 2), np.nan))
        with pytest.raises(ValueError):
            BipartiteGraph.from_edges(2, 2, [5], [0])

    def test_validate_catches_internal_corruption(self):
        g = BipartiteGraph(4, 4, np.array([9]), np.array([0]))
        with pytest.raises(GraphValidationError, match="out of range"):
            g.validate()
        g2 = BipartiteGraph(4, 4, np.array([1, 2]), np.array([0]))
        with pytest.raises(GraphValidationError, match="parallel"):
            g2.validate()
        ok = GRAPH_CASES["fig1"]()
        assert ok.validate() is ok


# --------------------------------------------------------------------- #
# degenerate graphs x dispatch x backend (satellite 3a)
# --------------------------------------------------------------------- #
DEGENERATE = {
    "empty_edges": GRAPH_CASES["empty_edges"],
    "star": GRAPH_CASES["star"],                     # butterfly-free
    "single_vertex_side": lambda: BipartiteGraph.from_edges(
        1, 5, [0] * 5, list(range(5))),
    "all_ones_dense": lambda: BipartiteGraph.from_dense(
        np.ones((8, 6))),
}


@pytest.mark.parametrize("name", sorted(DEGENERATE))
@pytest.mark.parametrize("dispatch", ["subset", "graph"])
@pytest.mark.parametrize("backend", ["xla", "interpret",
                                     "interpret_sparse"])
def test_degenerate_graphs_every_mode(name, dispatch, backend):
    g = DEGENERATE[name]()
    tb, _ = bup_oracle(g)
    td = Executor(_cfg(cd_dispatch=dispatch, backend=backend)).decompose(
        g, verify=True)
    np.testing.assert_array_equal(td.theta, tb)
    assert td.stats.verified and td.stats.verify_checks >= 1


# --------------------------------------------------------------------- #
# fault grammar (tentpole b)
# --------------------------------------------------------------------- #
class TestFaultSpec:
    def test_parse_full_grammar(self):
        spec = FaultSpec.parse(
            "kernel_launch:backend=interpret@2x3, peel_buffer@1, "
            "map_chunk, dgm_boundary@4x*")
        assert len(spec.rules) == 4
        r = spec.rules[0]
        assert (r.site, r.filters, r.nth, r.count) == (
            "kernel_launch", (("backend", "interpret"),), 2, 3)
        assert spec.rules[1].count == 1
        assert spec.rules[2].nth == 0          # bare site: every hit
        assert spec.rules[3].count == -1       # x*: unbounded

    def test_parse_rejects_unknown_site_with_hint(self):
        with pytest.raises(ValueError, match="kernel_launch"):
            FaultSpec.parse("kernel_lunch@1")
        with pytest.raises(ValueError, match="unknown fault-injection site"):
            FaultSpec.parse("bogus")
        with pytest.raises(ValueError):
            FaultSpec.parse("kernel_launch@0")        # 1-based

    def test_trigger_counting_and_filters(self):
        inj = FaultInjector("kernel_launch:backend=interpret@2")
        with inject(inj):
            assert not fault_point("kernel_launch", backend="xla")
            assert not fault_point("kernel_launch", backend="interpret")
            assert fault_point("kernel_launch", backend="interpret")
            assert not fault_point("kernel_launch", backend="interpret")
        assert inj.report() == [{
            "rule": "kernel_launch:backend=interpret@2",
            "hits": 3, "fired": 1}]

    def test_fault_point_raises_given_error_class(self):
        with inject(FaultInjector("map_chunk@1")):
            with pytest.raises(KernelBackendError) as ei:
                fault_point("map_chunk", KernelBackendError, chunk=0)
        assert ei.value.injected
        assert ei.value.context["site"] == "map_chunk"

    def test_engine_config_validates_fault_spec(self):
        with pytest.raises(ValueError, match="unknown fault-injection site"):
            _cfg(fault_spec="nope@1")


# --------------------------------------------------------------------- #
# graceful degradation (tentpole c)
# --------------------------------------------------------------------- #
class TestDegradation:
    def test_kernel_fault_falls_back_exactly(self):
        g = _er(40, 30, 200, 1)
        base = Executor(_cfg(backend="interpret")).decompose(g).theta
        ex = Executor(_cfg(backend="interpret",
                           fault_spec="kernel_launch:backend=interpret@1"))
        td = ex.decompose(g)
        np.testing.assert_array_equal(td.theta, base)
        assert td.stats.backend_used == "xla"
        assert td.stats.backend_fallbacks == ["interpret"]
        assert ex.cache_stats["fallback_runs"] == 1
        assert ex.fault_report[0]["fired"] == 1

    def test_repeated_failure_quarantines_signature(self):
        g = _er(40, 30, 200, 1)
        base = Executor(_cfg(backend="interpret")).decompose(g).theta
        ex = Executor(_cfg(backend="interpret",
                           fault_spec="kernel_launch:backend=interpret@1x*"))
        for _ in range(3):
            td = ex.decompose(g)
            np.testing.assert_array_equal(td.theta, base)
        # after _QUARANTINE_AFTER primary failures the signature runs
        # straight on the fallback backend: no more failed launches
        assert ex.cache_stats["quarantined"] == 1
        assert td.stats.quarantined
        assert td.stats.backend_used == "xla"
        assert td.stats.backend_fallbacks == []

    def test_chain_exhaustion_raises_structured(self):
        g = _er(30, 20, 100, 2)
        ex = Executor(_cfg(backend="xla",
                           fault_spec="kernel_launch:backend=xla@1x*"))
        with pytest.raises(KernelBackendError) as ei:
            ex.decompose(g)
        assert ei.value.plan_signature is not None
        assert "xla" in str(ei.value)

    @pytest.mark.parametrize("dispatch", ["subset", "graph"])
    def test_forced_peel_overflow_replay_is_exact(self, dispatch):
        g = _er(40, 30, 200, 1)
        base = Executor(_cfg(cd_dispatch=dispatch)).decompose(g).theta
        td = Executor(_cfg(cd_dispatch=dispatch,
                           fault_spec="peel_buffer@1")).decompose(g)
        np.testing.assert_array_equal(td.theta, base)
        assert td.stats.overflow_fallbacks >= 1

    def test_dgm_boundary_fault_recovers_on_fallback(self):
        g = _er(40, 30, 200, 1)
        base = Executor(_cfg(backend="interpret", cd_dispatch="subset",
                             use_dgm=True)).decompose(g).theta
        td = Executor(_cfg(backend="interpret", cd_dispatch="subset",
                           use_dgm=True,
                           fault_spec="dgm_boundary@1")).decompose(g)
        np.testing.assert_array_equal(td.theta, base)
        assert td.stats.backend_fallbacks == ["interpret"]

    def test_guardrails_off_propagates_and_suppresses(self):
        g = _er(30, 20, 100, 2)
        base = Executor(_cfg()).decompose(g).theta
        # guardrails=False suppresses this executor's own injector too:
        # the bare path must be byte-identical to an uninjected run
        ex = Executor(_cfg(fault_spec="kernel_launch@1x*"),
                      guardrails=False)
        np.testing.assert_array_equal(ex.decompose(g).theta, base)


# --------------------------------------------------------------------- #
# admission control (tentpole c)
# --------------------------------------------------------------------- #
class TestAdmissionControl:
    def test_infeasible_budget_raises(self):
        g = _er(40, 30, 200, 1)
        with pytest.raises(PlanInfeasibleError) as ei:
            Executor(_cfg(memory_budget_bytes=1024)).decompose(g)
        assert "budget" in str(ei.value)
        assert isinstance(ei.value, ValueError)

    def test_moderate_budget_downshifts_partitions(self):
        g = GRAPH_CASES["powerlaw"]()
        ex0 = Executor(_cfg(num_partitions=8))
        plan0 = ex0.plan(g)
        # find a budget that admits the fixed cost but not the 8-way
        # FD stack: walk down until the plan degrades
        budget = plan0.padded_bytes - 1
        ex = Executor(_cfg(num_partitions=8, memory_budget_bytes=budget))
        plan = ex.plan(g)
        assert plan.degraded_from_partitions == 8
        assert plan.num_partitions < 8
        assert plan.padded_bytes <= budget
        # the degraded plan still decomposes exactly
        tb, _ = bup_oracle(g)
        td = ex.decompose(g, plan=plan)
        np.testing.assert_array_equal(td.theta, tb)

    def test_no_budget_means_no_admission_control(self):
        g = GRAPH_CASES["er_small"]()
        plan = Executor(_cfg(num_partitions=4)).plan(g)
        assert plan.memory_budget_bytes is None
        assert plan.degraded_from_partitions is None


# --------------------------------------------------------------------- #
# fleet isolation (tentpole d) — the ISSUE acceptance scenario
# --------------------------------------------------------------------- #
class TestFleetIsolation:
    def _fleet(self):
        return [_er(16, 12, 60, s) for s in range(5)]

    def test_bad_member_isolated_healthy_bit_identical(self):
        fleet = self._fleet()
        clean = Executor(_cfg(fd_mode="level")).map(fleet)
        bad = BipartiteGraph(4, 4, np.array([9]), np.array([0]))
        fleet_bad = fleet[:2] + [bad] + fleet[2:]
        ex = Executor(_cfg(fd_mode="level", fault_spec="map_chunk@1"))
        res = ex.map(fleet_bad)
        assert len(res) == 6
        assert isinstance(res[2], GraphValidationError)
        assert res[2].context["graph_index"] == 2
        healthy = res[:2] + res[3:]
        for got, want in zip(healthy, clean):
            np.testing.assert_array_equal(got.theta, want.theta)
        rep = ex.last_map_report
        assert rep["chunk_failures"] >= 1          # the injected fault
        assert rep["chunk_retries"] + rep["isolated_graphs"] >= 1
        assert list(rep["errors"]) == [2]

    def test_strict_mode_aggregates(self):
        fleet = self._fleet()
        fleet[1] = BipartiteGraph(4, 4, np.array([9]), np.array([0]))
        ex = Executor(_cfg(fd_mode="level"))
        with pytest.raises(FleetPartialFailure) as ei:
            ex.map(fleet, strict=True)
        assert list(ei.value.errors) == [1]
        assert ei.value.n_ok == 4
        assert isinstance(ei.value.errors[1], GraphValidationError)

    def test_non_graph_member_reported_not_raised(self):
        fleet = self._fleet()
        res = Executor(_cfg(fd_mode="level")).map(fleet[:2] + ["nope"])
        assert isinstance(res[2], GraphValidationError)
        assert all(not isinstance(r, ReceiptError) for r in res[:2])

    def test_chunk_fault_retries_on_fallback_backend(self):
        fleet = self._fleet()
        clean = Executor(_cfg(fd_mode="level",
                              backend="interpret")).map(fleet)
        ex = Executor(_cfg(fd_mode="level", backend="interpret",
                           fault_spec="map_chunk:backend=interpret@1"))
        res = ex.map(fleet)
        for got, want in zip(res, clean):
            np.testing.assert_array_equal(got.theta, want.theta)
        rep = ex.last_map_report
        assert rep["chunk_retries"] >= 1
        assert not rep["errors"]
        # the retried chunk ran on the fallback backend
        assert any(r.stats.backend_used == "xla" for r in res)


# --------------------------------------------------------------------- #
# verify mode (tentpole e)
# --------------------------------------------------------------------- #
class TestVerifyMode:
    @pytest.mark.parametrize("name", ["fig1", "er_dense", "powerlaw"])
    def test_verify_passes_on_real_results(self, name):
        g = GRAPH_CASES[name]()
        td = Executor(_cfg(num_partitions=4)).decompose(g, verify=True)
        assert td.stats.verified
        assert td.stats.verify_checks >= 3

    def test_verify_rejects_upward_corruption(self):
        g = GRAPH_CASES["er_dense"]()
        td = Executor(_cfg(num_partitions=4)).decompose(g)
        bad = td.theta.copy()
        bad[0] = bad.max() + 3
        with pytest.raises(VerificationError):
            verify_tip_decomposition(g, "U", bad,
                                     bounds=td.stats.bounds)

    def test_verify_rejects_support_bound_violation(self):
        g = GRAPH_CASES["single_bfly"]()
        with pytest.raises(VerificationError, match="support"):
            verify_tip_decomposition(g, "U", np.array([5, 1]))

    def test_verify_rejects_shape_mismatch(self):
        g = GRAPH_CASES["fig1"]()
        with pytest.raises(VerificationError, match="shape"):
            verify_tip_decomposition(g, "U", np.zeros(3, np.int64))

    def test_verify_map_results_without_bounds(self):
        fleet = [_er(16, 12, 60, s) for s in range(3)]
        res = Executor(_cfg(fd_mode="level")).map(fleet)
        for g, r in zip(fleet, res):
            assert verify_tip_decomposition(g, "U", r.theta) >= 1


# --------------------------------------------------------------------- #
# satellite 2: RestartManager failure log + straggler flagging
# --------------------------------------------------------------------- #
def test_restart_manager_bounded_failure_log(tmp_path):
    from repro.train.checkpoint import CheckpointManager
    from repro.train.fault_tolerance import RestartManager

    rm = RestartManager(CheckpointManager(str(tmp_path)),
                        max_failures=1000, max_failure_log=5)
    for i in range(9):
        rm.record_failure(RuntimeError(f"boom {i}"))
    rep = rm.failure_report()
    assert rm.failures == 9
    assert len(rep) == 5                        # bounded, newest win
    assert [e["message"] for e in rep] == [f"boom {i}" for i in
                                           range(4, 9)]
    assert all(e["type"] == "RuntimeError" and "time" in e for e in rep)


def test_map_straggler_flagging_monkeypatched():
    """Stragglers surface in the report + per-result stats; chunk walls
    are fed to the shared StragglerMonitor (forced here by faking one
    slow chunk EWMA)."""
    fleet = [_er(16, 12, 60, s) for s in range(4)]
    ex = Executor(_cfg(fd_mode="level"), map_stack_cells=16 * 16)
    res = ex.map(fleet)                         # >= 3 chunks recorded
    assert len(ex._stragglers.timings) >= 3
    slow = next(iter(ex._stragglers.timings))
    ex._stragglers.timings[slow].ewma = 1e9     # fake a straggler
    res = ex.map(fleet)
    rep = ex.last_map_report
    assert slow in set(ex._stragglers.stragglers())
    assert rep["stragglers"]
