"""Optional-hypothesis shim (requirements-dev.txt pins the real thing).

``from _hypothesis_compat import given, settings, st`` gives the real
hypothesis API when installed; otherwise stand-ins that mark each property
test as skipped at collection time, so the rest of the module's tests
still run (a bare top-level ``import hypothesis`` used to fail collection
of four whole test files on minimal installs).
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on minimal installs
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Accepts any strategies.* call; values never materialize."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def settings(*a, **k):
        return lambda f: f

    def given(*a, **k):
        def deco(f):
            return pytest.mark.skip(
                reason="hypothesis not installed (see requirements-dev.txt)"
            )(f)

        return deco
