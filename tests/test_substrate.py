"""Substrate tests: optimizer, train step, checkpoint, scheduler,
fault-tolerance logic, sharding rules."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.scheduler import lpt_assign, pack_by_shape
from repro.train.checkpoint import CheckpointManager
from repro.train.fault_tolerance import ElasticMesh, RestartManager, StragglerMonitor
from repro.train.optimizer import (
    AdamWConfig, adamw_init, adamw_update, compress_int8, decompress_int8, lr_at,
)
from repro.train.train_step import init_train_state, make_train_step


# --------------------------------------------------------------------- #
# optimizer
# --------------------------------------------------------------------- #
def _quad_loss(params, batch):
    err = params["w"] - batch["target"]
    return jnp.sum(err * err), {}


def test_adamw_converges_on_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                      total_steps=1000, schedule="constant")
    params = {"w": jnp.zeros((4,))}
    state = init_train_state(params, cfg)
    step = jax.jit(make_train_step(_quad_loss, cfg))
    batch = {"target": jnp.array([1.0, -2.0, 3.0, 0.5])}
    for _ in range(300):
        state, metrics = step(state, batch)
    np.testing.assert_allclose(
        np.asarray(state["params"]["w"]), np.asarray(batch["target"]), atol=1e-2
    )


def test_grad_clip_bounds_update():
    cfg = AdamWConfig(lr=1.0, grad_clip=1e-3, weight_decay=0.0,
                      warmup_steps=0, schedule="constant")
    params = {"w": jnp.array([0.0])}
    opt = adamw_init(params, cfg)
    grads = {"w": jnp.array([1e9])}
    _, _, m = adamw_update(params, grads, opt, cfg)
    assert float(m["grad_norm"]) > 1e8  # reported pre-clip


def test_lr_schedule_warmup_and_decay():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110)
    assert float(lr_at(cfg, jnp.asarray(0))) == 0.0
    assert abs(float(lr_at(cfg, jnp.asarray(10))) - 1.0) < 1e-6
    assert float(lr_at(cfg, jnp.asarray(110))) < 1e-6


def test_microbatch_accumulation_matches_full_batch():
    cfg = AdamWConfig(lr=0.01, weight_decay=0.0, warmup_steps=0,
                      schedule="constant")
    params = {"w": jnp.ones((3,))}

    def loss(p, b):
        return jnp.mean((p["w"] * b["x"] - b["y"]) ** 2), {}

    rng = np.random.default_rng(0)
    batch = {
        "x": jnp.asarray(rng.normal(size=(8, 3)).astype(np.float32)),
        "y": jnp.asarray(rng.normal(size=(8, 3)).astype(np.float32)),
    }
    s1 = init_train_state(params, cfg)
    s2 = init_train_state(params, cfg)
    full = jax.jit(make_train_step(loss, cfg))
    micro = jax.jit(make_train_step(loss, cfg, microbatches=4))
    s1, _ = full(s1, batch)
    s2, _ = micro(s2, batch)
    np.testing.assert_allclose(
        np.asarray(s1["params"]["w"]), np.asarray(s2["params"]["w"]),
        rtol=1e-5, atol=1e-6,
    )


def test_int8_compression_error_feedback():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(64,)).astype(np.float32))
    err = jnp.zeros_like(g)
    total = jnp.zeros_like(g)
    # accumulated dequantized grads + error feedback converge to true sum
    acc_err = err
    for _ in range(50):
        q, s, acc_err = compress_int8(g, acc_err)
        total = total + decompress_int8(q, s)
    np.testing.assert_allclose(
        np.asarray(total) / 50, np.asarray(g), atol=2e-2
    )


# --------------------------------------------------------------------- #
# checkpoint
# --------------------------------------------------------------------- #
def _state():
    return {
        "params": {"a": jnp.arange(6.0).reshape(2, 3), "b": [jnp.ones(4)]},
        "opt": {"step": jnp.asarray(7, jnp.int32)},
    }


def test_checkpoint_roundtrip():
    with tempfile.TemporaryDirectory() as d:
        ck = CheckpointManager(d)
        s = _state()
        ck.save(5, s)
        r = ck.restore(jax.eval_shape(lambda: s))
        for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(r)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity_tmp_ignored():
    with tempfile.TemporaryDirectory() as d:
        ck = CheckpointManager(d)
        ck.save(1, _state())
        # a torn write (tmp dir without rename) must be invisible
        os.makedirs(os.path.join(d, "step_9.tmp"))
        assert ck.latest_step() == 1


def test_checkpoint_gc_keeps_latest():
    with tempfile.TemporaryDirectory() as d:
        ck = CheckpointManager(d, keep=2)
        for step in (1, 2, 3, 4):
            ck.save(step, _state())
        assert ck.all_steps() == [3, 4]


def test_checkpoint_async():
    with tempfile.TemporaryDirectory() as d:
        ck = CheckpointManager(d)
        ck.save(1, _state(), blocking=False)
        ck.wait()
        assert ck.latest_step() == 1


def test_restart_manager_resume():
    with tempfile.TemporaryDirectory() as d:
        rm = RestartManager(CheckpointManager(d), save_every=2)
        s = _state()
        rm.maybe_save(2, s, blocking=True)
        template = jax.eval_shape(lambda: s)
        restored, step = rm.resume_or_init(template)
        assert step == 2
        np.testing.assert_array_equal(
            np.asarray(restored["params"]["a"]), np.asarray(s["params"]["a"])
        )


# --------------------------------------------------------------------- #
# scheduler / straggler
# --------------------------------------------------------------------- #
def test_lpt_assign_balances():
    w = [10, 9, 8, 2, 2, 2, 1]
    plan = lpt_assign(w, 2)
    loads = [sum(w[i] for i in grp) for grp in plan]
    assert abs(loads[0] - loads[1]) <= 4  # LPT bound for this instance


@settings(max_examples=30, deadline=None)
@given(
    weights=st.lists(st.floats(0.1, 100), min_size=1, max_size=40),
    k=st.integers(1, 8),
)
def test_property_lpt_is_complete_and_bounded(weights, k):
    plan = lpt_assign(weights, k)
    seen = sorted(i for grp in plan for i in grp)
    assert seen == list(range(len(weights)))       # every task placed once
    loads = [sum(weights[i] for i in grp) for grp in plan]
    # directly provable greedy bound: the last job assigned to the max
    # worker started no later than avg, so
    #   max_load <= sum/k + max_w * (k-1)/k
    # (Graham's 4/3 holds vs OPT, which is NOT certifiable from a lower
    # bound — hypothesis found the counterexample; see git history)
    bound = sum(weights) / k + max(weights) * (k - 1) / k
    assert max(loads) <= bound + 1e-6


def test_pack_by_shape_groups_and_orders():
    tasks = [
        {"r": 5, "c": 5, "w": 1},
        {"r": 6, "c": 7, "w": 9},
        {"r": 30, "c": 3, "w": 4},
    ]
    groups = pack_by_shape(
        tasks,
        size_of=lambda t: (t["r"], t["c"]),
        weight_of=lambda t: t["w"],
        bucket=lambda n: 8 if n <= 8 else 32,
    )
    # two groups: (8,8) and (32,8); heaviest-first inside
    assert len(groups) == 2
    small = [g for g in groups if len(g) == 2][0]
    assert small[0]["w"] >= small[1]["w"]


def test_straggler_monitor_flags_slow_task():
    mon = StragglerMonitor(threshold=2.0)
    for t in range(6):
        mon.record(f"task{t}", 1.0)
    mon.record("slow", 10.0)
    assert "slow" in mon.stragglers()
    plan = mon.speculative_plan([f"task{t}" for t in range(6)] + ["slow"], 3)
    placed = [i for grp in plan for i in grp]
    assert len(placed) >= 7                        # duplicate scheduled


def test_elastic_mesh_shrinks_preserving_model_axis():
    class FakeDev:
        def __init__(self, i):
            self.id = i

    em = ElasticMesh([FakeDev(i) for i in range(8)], model_axis=2)
    m = em.make_mesh()
    assert m.shape["model"] == 2 and m.shape["data"] == 4
    em.mark_failed([6, 7])
    m2 = em.make_mesh()
    assert m2.shape["model"] == 2 and m2.shape["data"] == 3


# --------------------------------------------------------------------- #
# sharding rules
# --------------------------------------------------------------------- #
def test_sharding_rules_divisibility_fallback():
    import re

    from repro.launch.mesh import make_mesh  # noqa: F401
    from repro.launch.sharding import _check_div

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 4, "model": 2}

    spec = _check_div((6, 8), ("data", "model"), FakeMesh())
    # 6 % 4 != 0 -> dropped; 8 % 2 == 0 -> kept
    assert spec == jax.sharding.PartitionSpec(None, "model")


def test_lm_param_specs_match_paths():
    from repro.configs import get_bundle

    b = get_bundle("deepseek-v3-671b", reduced=True)
    # use a fake mesh-like object compatible with _check_div/axis_size
    import jax as _jax

    mesh = _jax.make_mesh((1, 1), ("data", "model"))
    specs = b.param_shardings(mesh)
    flat, _ = jax.tree_util.tree_flatten_with_path(specs)
    from repro.launch.sharding import norm_path

    by_path = {norm_path(p): s.spec for p, s in flat}
    # spot-check rule hits (axis size 1 keeps divisibility => names kept)
    assert by_path["layers/moe/gate"][1] == "model"       # EP on experts
    assert by_path["embed"][0] == "model"                 # vocab sharded
    assert by_path["layers/attn/wkv_b"][2] == "model"     # MLA up-proj TP
