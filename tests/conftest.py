"""Shared pytest fixtures.

NOTE: XLA_FLAGS / device-count manipulation is deliberately NOT done here —
smoke tests and benches must see the single real CPU device.  Multi-device
tests spawn subprocesses with their own XLA_FLAGS (test_distributed.py,
test_dryrun.py).
"""
import numpy as np
import pytest

from repro.core.graph import (
    BipartiteGraph,
    paper_fig1_graph,
    powerlaw_bipartite,
    random_bipartite,
)


@pytest.fixture
def fig1():
    return paper_fig1_graph()


def make_vhub_graph(n_u=300, n_v=60, n_hubs=6, seed=0) -> BipartiteGraph:
    """TrU-like regime: V-side hubs, light U side (r >> 1, HUC fires)."""
    rng = np.random.default_rng(seed)
    eu, ev = [], []
    for u in range(n_u):
        hubs = rng.choice(n_hubs, size=rng.integers(1, 3), replace=False)
        light = n_hubs + rng.choice(
            n_v - n_hubs, size=rng.integers(1, 4), replace=False
        )
        cols = list(hubs) + list(light)
        eu += [u] * len(cols)
        ev += list(cols)
    return BipartiteGraph.from_edges(n_u, n_v, eu, ev)


GRAPH_CASES = {
    "fig1": lambda: paper_fig1_graph(),
    "er_small": lambda: random_bipartite(50, 30, 0.15, seed=3),
    "er_dense": lambda: random_bipartite(40, 25, 0.45, seed=4),
    "powerlaw": lambda: powerlaw_bipartite(200, 120, 1500, seed=5),
    "vhub": lambda: make_vhub_graph(seed=6),
    "empty_edges": lambda: BipartiteGraph.from_edges(10, 8, [], []),
    "single_bfly": lambda: BipartiteGraph.from_edges(
        2, 2, [0, 0, 1, 1], [0, 1, 0, 1]
    ),
    "star": lambda: BipartiteGraph.from_edges(
        20, 1, list(range(20)), [0] * 20
    ),
}
