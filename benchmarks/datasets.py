"""Benchmark datasets: synthetic bipartite graphs mirroring the paper's
dataset regimes (Table 2), scaled to CPU-minutes.

  * itu_like  — power-law both sides (ItU: moderate r, many subsets)
  * tru_like  — V-side hubs + light U (TrU: r >> 1, HUC regime)
  * dev_like  — dense-ish uniform (DeV: low r, counting-dominated)
  * orv_like  — larger power-law, V orientation (peel the lighter side)
"""
from __future__ import annotations

import numpy as np

from repro.core.graph import BipartiteGraph, powerlaw_bipartite, random_bipartite


def tru_like(n_u=1200, n_v=160, n_hubs=10, seed=7) -> BipartiteGraph:
    rng = np.random.default_rng(seed)
    eu, ev = [], []
    for u in range(n_u):
        hubs = rng.choice(n_hubs, size=rng.integers(1, 4), replace=False)
        light = n_hubs + rng.choice(
            n_v - n_hubs, size=rng.integers(1, 4), replace=False
        )
        cols = list(hubs) + list(light)
        eu += [u] * len(cols)
        ev += list(cols)
    return BipartiteGraph.from_edges(n_u, n_v, eu, ev)


def itu_like(seed=3) -> BipartiteGraph:
    return powerlaw_bipartite(1000, 500, 8000, alpha_u=2.1, alpha_v=1.9, seed=seed)


def dev_like(seed=4) -> BipartiteGraph:
    return random_bipartite(400, 300, 0.06, seed=seed)


def orv_like(seed=5) -> BipartiteGraph:
    g = powerlaw_bipartite(900, 1400, 9000, alpha_u=1.9, alpha_v=2.2, seed=seed)
    # peel the other side: swap U and V
    return BipartiteGraph.from_edges(g.n_v, g.n_u, g.edges_v, g.edges_u)


DATASETS = {
    "itu_like": itu_like,
    "tru_like": tru_like,
    "dev_like": dev_like,
    "orv_like": orv_like,
}
