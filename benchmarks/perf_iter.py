"""Perf-iteration driver: structural profile of one dry-run cell.

    PYTHONPATH=src python -m benchmarks.perf_iter <arch> <shape> [--multi-pod]

Compiles the cell on the production mesh and prints the three roofline
terms + the top memory/wire/flops sites from the trip-count-aware HLO
cost model — the "profile" each hypothesis->change->measure iteration
reads (there is no wall clock on CPU; the lowered IR is the profile).
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import sys


def main():
    args = [a for a in sys.argv[1:] if not a.startswith("--")]
    multi = "--multi-pod" in sys.argv
    arch, shape = args[0], args[1]

    from repro.launch.dryrun import dryrun_cell
    from repro.launch import roofline as rl
    from repro.utils.hlo_cost import analyze_text

    # dryrun_cell already prints the three terms; we want the site tables
    # too, so we rebuild the compile here for receipt/model cells alike.
    import jax
    from jax.sharding import NamedSharding, PartitionSpec
    from repro.launch.mesh import make_production_mesh
    from repro.launch.sharding import mesh_context

    mesh = make_production_mesh(multi_pod=multi)

    if arch == "receipt-tip":
        from repro.configs.shapes import RECEIPT_SHAPES
        from repro.core import distributed as dist

        s = RECEIPT_SHAPES[shape]
        with mesh, mesh_context(mesh):
            if s.kind == "cd_sweep":
                lowered = dist.lower_cd_sweep(
                    mesh, n_u=s.n_u, n_v=s.n_v, peel_rows=s.peel_rows)
            else:
                lowered = dist.lower_fd_stack(
                    mesh, n_subsets=s.n_subsets, rows=s.subset_rows,
                    cols=s.subset_cols)
            comp = lowered.compile()
    else:
        from repro.configs import get_bundle

        b = get_bundle(arch)
        kind, step = b.step_for(shape)
        specs = b.input_specs(shape)
        in_shard = b.input_shardings(shape, mesh)
        with mesh, mesh_context(mesh):
            if kind.startswith("train"):
                state_abs = b.state_abstract()
                state_shard = b.state_shardings(mesh)
                out_abs = jax.eval_shape(step, state_abs, specs)
                mshard = jax.tree.map(
                    lambda _: NamedSharding(mesh, PartitionSpec()), out_abs[1])
                comp = jax.jit(
                    step, in_shardings=(state_shard, in_shard),
                    out_shardings=(state_shard, mshard), donate_argnums=(0,),
                ).lower(state_abs, specs).compile()
            else:
                params_abs = b.abstract_params()
                pspec = b.param_shardings(mesh)
                comp = jax.jit(
                    step, in_shardings=(pspec, in_shard),
                ).lower(params_abs, specs).compile()

    c = analyze_text(comp.as_text())
    ma = comp.memory_analysis()
    args_b = getattr(ma, "argument_size_in_bytes", 0)
    temp_b = getattr(ma, "temp_size_in_bytes", 0)
    print(f"\n=== {arch} {shape} mesh={'2x16x16' if multi else '16x16'} ===")
    print(f"mem/dev: args={args_b/1e9:.2f}GB temp={temp_b/1e9:.2f}GB "
          f"total={(args_b+temp_b)/1e9:.2f}GB (HBM=16GB)")
    print(f"t_compute={c.flops/rl.PEAK_FLOPS*1e3:9.2f}ms  "
          f"t_memory={c.hbm_bytes/rl.HBM_BW*1e3:9.2f}ms  "
          f"t_collective={c.wire_bytes/rl.ICI_BW*1e3:9.2f}ms")
    print(f"flops={c.flops:.3e}  hbm={c.hbm_bytes/1e9:.1f}GB  "
          f"wire={c.wire_bytes/1e9:.1f}GB  n_coll={int(c.n_collectives)}")
    for field, title in (("mem_by_site", "MEMORY"), ("wire_by_site", "WIRE"),
                         ("flops_by_site", "FLOPS")):
        print(f"\nTOP {title} SITES:")
        for k, v in c.top(field, 10):
            unit = 1e12 if field == "flops_by_site" else 1e9
            u = "T" if field == "flops_by_site" else "GB"
            print(f"  {v/unit:10.2f}{u}  {k}")


if __name__ == "__main__":
    main()
