"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (comment lines start '#').

    table3_time     exec time: BUP vs ParB-emulation vs RECEIPT   (Table 3 t)
    table3_wedges   wedges traversed                              (Table 3 ∧)
    table3_sync     synchronization rounds rho                    (Table 3 ρ)
    fig5_psweep     RECEIPT time vs P                             (Fig 5)
    fig67_ablation  HUC/DGM ablations (RECEIPT--/-/full)          (Figs 6-7)
    fig89_breakup   wedge & time breakup per phase                (Figs 8-9)
    fig1011_scaling multi-device scaling of the distributed engine(Figs 10-11)
    kernel_bench    butterfly kernel: dense blocked vs segment
"""
from __future__ import annotations

import sys
import time
from typing import Callable, Dict

import numpy as np

sys.path.insert(0, "src")

from repro.core.peeling import bup_oracle, parb_metrics
from repro.core.receipt import ReceiptConfig, parb_tip_decompose, tip_decompose

from .datasets import DATASETS

BLOCKS = (8, 8, 8)


def _cfg(**kw):
    base = dict(num_partitions=24, kernel_blocks=BLOCKS, backend="xla")
    base.update(kw)
    return ReceiptConfig(**base)


def _time(fn, *args, repeat=1, **kw):
    t0 = time.perf_counter()
    out = None
    for _ in range(repeat):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeat
    return dt, out


_ORACLE_CACHE: Dict[str, tuple] = {}


def _oracle(name, g):
    if name not in _ORACLE_CACHE:
        dt_b, (tb, mb) = _time(bup_oracle, g)
        dt_p, (tp, mp) = _time(parb_metrics, g)
        _ORACLE_CACHE[name] = (dt_b, tb, mb, dt_p, tp, mp)
    return _ORACLE_CACHE[name]


def table3_time(rows):
    """Wall time: RECEIPT vs ParB on the SAME engine/kernels (the only
    difference is the peel schedule => sync rounds), plus the numpy BUP
    oracle as a host reference point.  First call per config warms the
    jit caches and is not timed (the paper times steady-state too)."""
    for name, make in DATASETS.items():
        g = make()
        dt_b, tb, mb, dt_p, tp, mp = _oracle(name, g)
        tip_decompose(g, _cfg())                      # warm-up (compile)
        dt_r, (tr, st) = _time(tip_decompose, g, _cfg())
        parb_tip_decompose(g, _cfg())                 # warm-up (compile)
        dt_pe, (tpe, st_p) = _time(parb_tip_decompose, g, _cfg())
        assert (tr == tb).all(), f"{name}: RECEIPT != BUP"
        assert (tpe == tb).all(), f"{name}: ParB engine != BUP"
        rows.append((f"table3_time/bup_oracle/{name}", dt_b * 1e6, "host numpy"))
        rows.append((
            f"table3_time/parb_engine/{name}", dt_pe * 1e6,
            f"rho={st_p.rho_cd}",
        ))
        rows.append((
            f"table3_time/receipt/{name}", dt_r * 1e6,
            f"rho={st.rho_cd} speedup_vs_parb={dt_pe/dt_r:.2f}x",
        ))


def table3_wedges(rows):
    for name, make in DATASETS.items():
        g = make()
        _, tb, mb, _, _, _ = _oracle(name, g)
        _, (tr, st) = _time(tip_decompose, g, _cfg())
        bup_total = mb.wedges_static + st.wedges_pvbcnt  # BUP also counts
        rows.append((
            f"table3_wedges/{name}", 0.0,
            f"bup={bup_total} receipt={st.wedges_total} "
            f"reduction={bup_total/max(st.wedges_total,1):.2f}x "
            f"pv={st.wedges_pvbcnt} cd={st.wedges_cd} fd={st.wedges_fd}",
        ))


def table3_sync(rows):
    for name, make in DATASETS.items():
        g = make()
        _, tb, mb, _, _, mp = _oracle(name, g)
        _, (tr, st) = _time(tip_decompose, g, _cfg())
        rows.append((
            f"table3_sync/{name}", 0.0,
            f"parb_rho={mp.rounds} receipt_rho={st.rho_cd} "
            f"reduction={mp.rounds/max(st.rho_cd,1):.1f}x",
        ))


def fig5_psweep(rows):
    g = DATASETS["itu_like"]()
    for p in (4, 12, 24, 48, 96):
        tip_decompose(g, _cfg(num_partitions=p))      # warm-up (compile)
        dt, (tr, st) = _time(tip_decompose, g, _cfg(num_partitions=p))
        rows.append((
            f"fig5_psweep/P={p}", dt * 1e6,
            f"subsets={st.num_subsets} rho={st.rho_cd} wedges={st.wedges_total}",
        ))


def fig67_ablation(rows):
    for name in ("tru_like", "itu_like"):
        g = DATASETS[name]()
        variants = {
            "receipt--": _cfg(use_huc=False, use_dgm=False),
            "receipt-": _cfg(use_huc=True, use_dgm=False),
            "receipt": _cfg(use_huc=True, use_dgm=True),
        }
        base = None
        for vn, cfg in variants.items():
            tip_decompose(g, cfg)                     # warm-up (compile)
            dt, (tr, st) = _time(tip_decompose, g, cfg)
            base = base or st.wedges_total
            rows.append((
                f"fig67_ablation/{name}/{vn}", dt * 1e6,
                f"wedges={st.wedges_total} norm={st.wedges_total/base:.3f} "
                f"huc={st.huc_recounts} dgm={st.dgm_compactions}",
            ))


def fig89_breakup(rows):
    for name, make in DATASETS.items():
        g = make()
        _, (tr, st) = _time(tip_decompose, g, _cfg())
        tot_w = max(st.wedges_total, 1)
        tot_t = max(st.time_count + st.time_cd + st.time_fd, 1e-9)
        rows.append((
            f"fig89_breakup/{name}", 0.0,
            f"wedge%: pv={100*st.wedges_pvbcnt/tot_w:.1f} "
            f"cd={100*st.wedges_cd/tot_w:.1f} fd={100*st.wedges_fd/tot_w:.1f} | "
            f"time%: cnt={100*st.time_count/tot_t:.1f} "
            f"cd={100*st.time_cd/tot_t:.1f} fd={100*st.time_fd/tot_t:.1f}",
        ))


def fig1011_scaling(rows):
    """Distributed-engine scaling over forced host devices (subprocess)."""
    import json
    import subprocess

    script = r"""
import os, sys, json, time
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={sys.argv[1]}"
sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_mesh
from repro.core.distributed import distributed_butterfly_support
n_dev = int(sys.argv[1])
mesh = make_mesh((1, n_dev), ("data", "model"))
rng = np.random.default_rng(0)
a = jnp.asarray((rng.random((2048, 2048)) < 0.02).astype(np.float32))
s = jnp.ones((2048,), jnp.float32)
out = distributed_butterfly_support(mesh, a, s)  # compile
out.block_until_ready()
t0 = time.perf_counter()
for _ in range(3):
    out = distributed_butterfly_support(mesh, a, s)
    out.block_until_ready()
print(json.dumps({"dt": (time.perf_counter() - t0) / 3, "check": float(out.sum())}))
"""
    base = None
    check0 = None
    for nd in (1, 2, 4, 8):
        try:
            res = subprocess.run(
                [sys.executable, "-c", script, str(nd)],
                capture_output=True, text=True, timeout=900,
            )
            data = json.loads(res.stdout.strip().splitlines()[-1])
            dt = data["dt"]
            base = base or dt
            check0 = check0 if check0 is not None else data["check"]
            assert abs(data["check"] - check0) < 1e-3 * max(abs(check0), 1)
            rows.append((
                f"fig1011_scaling/devices={nd}", dt * 1e6,
                f"speedup={base/dt:.2f}x "
                "(CAVEAT: forced host devices share one CPU socket; "
                "intra-op threading already saturates cores at 1 device, "
                "so wall-clock scaling inverts — the dry-run collective "
                "analysis in EXPERIMENTS.md is the scalability evidence)",
            ))
        except Exception as e:  # pragma: no cover
            rows.append((f"fig1011_scaling/devices={nd}", 0.0, f"error={e}"))


def kernel_bench(rows):
    import jax
    import jax.numpy as jnp

    from repro.core.counting import (
        butterfly_counts_dense,
        butterfly_counts_segment,
        wedge_pair_table,
    )
    from repro.core.graph import powerlaw_bipartite

    g = powerlaw_bipartite(2048, 1024, 30000, seed=11)
    a = jnp.asarray(g.dense())
    fn = jax.jit(lambda a: butterfly_counts_dense(a, backend="xla"))
    fn(a).block_until_ready()
    dt, out = _time(lambda: fn(a).block_until_ready(), repeat=5)
    flops = 2.0 * a.shape[0] ** 2 * a.shape[1]
    rows.append((
        "kernel_bench/dense_xla", dt * 1e6,
        f"gflops={flops/dt/1e9:.1f} n_u={a.shape[0]} n_v={a.shape[1]}",
    ))

    us, ups = wedge_pair_table(g)
    usj, upsj = jnp.asarray(us), jnp.asarray(ups)
    seg = jax.jit(lambda u, v: butterfly_counts_segment(u, v, g.n_u))
    seg(usj, upsj).block_until_ready()
    dt2, _ = _time(lambda: seg(usj, upsj).block_until_ready(), repeat=5)
    rows.append((
        "kernel_bench/segment", dt2 * 1e6,
        f"wedges={len(us)} wedges_per_s={len(us)/dt2/1e6:.1f}M",
    ))

    # zero-stripe (block-sparse) opportunity after degree sorting: the
    # fraction of (BI x BK) A-tiles that are all-zero = the compute the
    # Pallas kernel's skip list removes (EXPERIMENTS.md kernel section)
    gs = g.relabel_by_degree()
    ad = gs.dense()
    for bi, bk in ((128, 512), (256, 512)):
        nu = (ad.shape[0] + bi - 1) // bi
        nv = (ad.shape[1] + bk - 1) // bk
        import numpy as _np

        pad = _np.zeros((nu * bi, nv * bk), ad.dtype)
        pad[: ad.shape[0], : ad.shape[1]] = ad
        tiles = pad.reshape(nu, bi, nv, bk).sum(axis=(1, 3))
        frac = float((tiles == 0).mean())
        rows.append((
            f"kernel_bench/tile_sparsity/{bi}x{bk}", 0.0,
            f"zero_tile_frac={frac:.3f} (degree-sorted powerlaw graph)",
        ))

    # staircase stripe-skip fraction for the block-sparse Pallas variant,
    # at production block sizes on a production-sparsity graph (the small
    # dense bench graph above has only 2 k-stripes, so skip=0 there)
    from repro.kernels.butterfly_sparse import column_extents

    g_sp = powerlaw_bipartite(16384, 16384, 120_000, seed=13).relabel_by_degree()
    ad_sp = g_sp.dense()
    for bi, bk in ((128, 512), (256, 512)):
        nu = ((ad_sp.shape[0] + bi - 1) // bi) * bi
        nv = ((ad_sp.shape[1] + bk - 1) // bk) * bk
        pad = _np.zeros((nu, nv), ad_sp.dtype)
        pad[: ad_sp.shape[0], : ad_sp.shape[1]] = ad_sp
        kmax = column_extents(pad, bi, bk)
        n_i, n_k = nu // bi, nv // bk
        skipped = sum(
            max(0, n_k - min(int(kmax[i]), int(kmax[j])))
            for i in range(n_i) for j in range(n_i)
        )
        rows.append((
            f"kernel_bench/stripe_skip/{bi}x{bk}", 0.0,
            f"skipped_stripe_frac={skipped/(n_i*n_i*n_k):.3f} "
            f"(16384x16384 m=102k powerlaw; MXU-step cut for "
            "butterfly_support_pallas_sparse)",
        ))


def wing_ext(rows):
    """Paper section 7 extension: wing decomposition (edge peeling)."""
    from repro.core.graph import random_bipartite
    from repro.core.wing import wing_bup_oracle, wing_decompose

    g = random_bipartite(24, 18, 0.3, seed=9)
    dt_o, (po, rounds) = _time(wing_bup_oracle, g)
    wing_decompose(g, num_partitions=6)               # warm-up (compile)
    dt_w, (pr, st) = _time(wing_decompose, g, num_partitions=6)
    assert (po == pr).all(), "wing != oracle"
    rows.append((
        "wing_ext/oracle", dt_o * 1e6, f"m={g.m} rounds={rounds}",
    ))
    rows.append((
        "wing_ext/receipt_cd_fd", dt_w * 1e6,
        f"rho_cd={st.rho_cd} subsets={st.num_subsets} "
        f"sync_reduction={rounds/max(st.rho_cd,1):.1f}x",
    ))


BENCHES = [
    table3_time, table3_wedges, table3_sync, fig5_psweep,
    fig67_ablation, fig89_breakup, fig1011_scaling, kernel_bench,
    wing_ext,
]


def main() -> None:
    rows = []
    for bench in BENCHES:
        t0 = time.time()
        try:
            bench(rows)
        except Exception as e:  # keep the harness running
            import traceback

            traceback.print_exc()
            rows.append((f"{bench.__name__}/ERROR", 0.0, str(e)))
        print(f"# {bench.__name__} done in {time.time()-t0:.1f}s", flush=True)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")

    # append the dry-run roofline table when available (EXPERIMENTS.md §Roofline)
    import os

    if os.path.exists("results/dryrun.json"):
        print("\n# ===== roofline table (from results/dryrun.json) =====")
        from . import roofline_report

        roofline_report.main("results/dryrun.json")


if __name__ == "__main__":
    main()
