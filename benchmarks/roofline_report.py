"""Render the EXPERIMENTS.md roofline table from results/dryrun.json.

    PYTHONPATH=src python -m benchmarks.roofline_report [results/dryrun.json]
    PYTHONPATH=src python -m benchmarks.roofline_report --tiled BENCH.json

Per (arch x shape x mesh): the three roofline terms, dominant bottleneck,
MODEL_FLOPS/HLO_FLOPs utility ratio, peak-memory check, and the
roofline fraction (t_compute / t_bound).  Also nominates the three
hillclimb cells (worst fraction / most collective-bound / most
paper-representative).

``--tiled`` instead renders the dense-vs-tiled representation roofline
from a bench_receipt.py JSON (the ISSUE 7 ``representations`` section):
per graph, the bytes each representation holds resident and the
count-sweep flops it issues.  The flops ratio IS the tile occupancy —
the band-streaming update does ``2 * n_slots * bi^2 * bk`` flops per
row band against dense's ``2 * rows^2 * cols`` whole-matrix product,
which cancels to ``n_tiles / (n_row_tiles * n_col_tiles)`` — so the
table makes the cost model's routing inputs auditable next to the
measured walls.
"""
from __future__ import annotations

import json
import sys


def fmt_t(x):
    if x is None:
        return "-"
    if x >= 1.0:
        return f"{x:8.2f}s "
    return f"{x*1e3:8.2f}ms"


def fmt_b(x):
    if not x:
        return "    -"
    return f"{x/1e9:7.2f}GB"


def tiled_table(path="BENCH_receipt.json"):
    """Dense-vs-tiled representation roofline from a bench JSON."""
    payload = json.load(open(path))
    rep = payload.get("representations")
    if not rep:
        print(f"{path}: no 'representations' section (run "
              "benchmarks/bench_receipt.py from this checkout)")
        return 1
    print("| graph | occ | routed | dense bytes | tiled bytes | "
          "bytes ratio | dense sweep flops | tiled sweep flops | "
          "warm wall ratio |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in rep.get("graphs", []):
        db, tb = r.get("dense_bytes"), r.get("tiled_bytes")
        if db is None or tb is None:
            continue                    # pre-ISSUE-7 baseline record
        occ = r["tile_occupancy"]
        # one whole-graph count sweep: dense W = A A^T is
        # 2 * rows^2 * cols flops; the tiled band-streaming oracle does
        # the occupancy fraction of it (zero tiles have no slot)
        dense_flops = 2.0 * r["n_u"] * r["n_u"] * r["n_v"]
        tiled_flops = occ * dense_flops
        print(f"| {r['name']} | {occ:.3f} | {r['routed']} "
              f"| {db / 2**20:7.1f}MiB | {tb / 2**20:7.1f}MiB "
              f"| {tb / db:.3f} "
              f"| {dense_flops:.2e} | {tiled_flops:.2e} "
              f"| {r['wall_ratio_warm']:.2f} |")
    meas = rep.get("measured") or {}
    lo = meas.get("max_tiled_win_occupancy")
    if lo is not None:
        print(f"\nmeasured crossover: tiled wins on wall up to "
              f"occupancy {lo:.3f} (routing constant "
              f"{rep.get('occupancy_crossover')})")
    return 0


def main(path="results/dryrun.json"):
    recs = [r for r in json.load(open(path)) if r.get("ok")]
    recs.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))

    print("| arch | shape | mesh | t_compute | t_memory | t_collective | "
          "bound | mem/dev | useful_flops | roofline_frac |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        ro = r["roofline"]
        ma = r.get("memory_analysis") or {}
        mem = (ma.get("argument_size_in_bytes", 0)
               + ma.get("temp_size_in_bytes", 0))
        uf = ro.get("useful_flops_fraction")
        rf = ro.get("roofline_fraction")
        uf_s = f"{uf:.3f}" if uf is not None else "-"
        rf_s = f"{rf:.3f}" if rf is not None else "-"
        print(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {fmt_t(ro['t_compute_s'])} | {fmt_t(ro['t_memory_s'])} "
            f"| {fmt_t(ro['t_collective_s'])} | {ro['bottleneck']} "
            f"| {fmt_b(mem)} | {uf_s} | {rf_s} |"
        )

    # hillclimb nominations (single-pod cells only, per the spec)
    sp = [r for r in recs if r["mesh"] == "16x16" and r["arch"] != "receipt-tip"]
    def frac(r):
        return r["roofline"].get("roofline_fraction") or 0.0
    worst = min(sp, key=frac)
    coll = max(sp, key=lambda r: r["roofline"]["t_collective_s"]
               / max(r["roofline"]["t_compute_s"]
                     + r["roofline"]["t_memory_s"]
                     + r["roofline"]["t_collective_s"], 1e-12))
    print("\n# hillclimb nominations")
    print(f"worst roofline fraction : {worst['arch']} {worst['shape']} "
          f"(frac={frac(worst):.3f})")
    print(f"most collective-bound   : {coll['arch']} {coll['shape']}")
    print("paper-representative    : receipt-tip cd_sweep_1m")


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "--tiled":
        sys.exit(tiled_table(*sys.argv[2:]))
    main(*sys.argv[1:])
