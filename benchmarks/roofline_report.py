"""Render the EXPERIMENTS.md roofline table from results/dryrun.json.

    PYTHONPATH=src python -m benchmarks.roofline_report [results/dryrun.json]

Per (arch x shape x mesh): the three roofline terms, dominant bottleneck,
MODEL_FLOPS/HLO_FLOPs utility ratio, peak-memory check, and the
roofline fraction (t_compute / t_bound).  Also nominates the three
hillclimb cells (worst fraction / most collective-bound / most
paper-representative).
"""
from __future__ import annotations

import json
import sys


def fmt_t(x):
    if x is None:
        return "-"
    if x >= 1.0:
        return f"{x:8.2f}s "
    return f"{x*1e3:8.2f}ms"


def fmt_b(x):
    if not x:
        return "    -"
    return f"{x/1e9:7.2f}GB"


def main(path="results/dryrun.json"):
    recs = [r for r in json.load(open(path)) if r.get("ok")]
    recs.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))

    print("| arch | shape | mesh | t_compute | t_memory | t_collective | "
          "bound | mem/dev | useful_flops | roofline_frac |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        ro = r["roofline"]
        ma = r.get("memory_analysis") or {}
        mem = (ma.get("argument_size_in_bytes", 0)
               + ma.get("temp_size_in_bytes", 0))
        uf = ro.get("useful_flops_fraction")
        rf = ro.get("roofline_fraction")
        uf_s = f"{uf:.3f}" if uf is not None else "-"
        rf_s = f"{rf:.3f}" if rf is not None else "-"
        print(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {fmt_t(ro['t_compute_s'])} | {fmt_t(ro['t_memory_s'])} "
            f"| {fmt_t(ro['t_collective_s'])} | {ro['bottleneck']} "
            f"| {fmt_b(mem)} | {uf_s} | {rf_s} |"
        )

    # hillclimb nominations (single-pod cells only, per the spec)
    sp = [r for r in recs if r["mesh"] == "16x16" and r["arch"] != "receipt-tip"]
    def frac(r):
        return r["roofline"].get("roofline_fraction") or 0.0
    worst = min(sp, key=frac)
    coll = max(sp, key=lambda r: r["roofline"]["t_collective_s"]
               / max(r["roofline"]["t_compute_s"]
                     + r["roofline"]["t_memory_s"]
                     + r["roofline"]["t_collective_s"], 1e-12))
    print("\n# hillclimb nominations")
    print(f"worst roofline fraction : {worst['arch']} {worst['shape']} "
          f"(frac={frac(worst):.3f})")
    print(f"most collective-bound   : {coll['arch']} {coll['shape']}")
    print("paper-representative    : receipt-tip cd_sweep_1m")


if __name__ == "__main__":
    main(*sys.argv[1:])
