"""RECEIPT engine benchmark: device-resident vs host-driven sweep loops.

Runs RECEIPT and the ParB baseline on the synthetic power-law interaction
graphs (src/repro/data/synthetic.py — the KONECT-shaped workload of the
paper's Table 3) with the fused ``lax.while_loop`` engine ON and OFF, and
writes ``BENCH_receipt.json`` with, per graph and engine:

  * wall clock (cold = includes jit, warm = steady-state best-of-3),
  * blocking host round trips (RunStats.host_round_trips) — the
    dispatch-layer analogue of the paper's synchronization counter rho,
  * rho_cd / rho_fd / wedge counters / HUC / DGM / elision counters,
  * FD runtime shape: shape-group count, stack padding waste,
  * derived reductions (host-loop RTs / device-loop RTs, wall speedups,
    FD level-peel vs the PR 1 sequential-peel baseline).

Engines: ``receipt_device`` (fused per-subset CD loop + FD level-peel,
the default stack), ``receipt_graph`` (whole-graph single-dispatch CD —
cd_dispatch="graph", the ISSUE 3 tentpole), ``receipt_fd_b2`` (fused CD
loop + the PR 1 sequential FD — the FD baseline), ``receipt_host`` /
``parb_*`` (round-trip comparators).  A separate CD-phase-only
measurement records the tentpole metrics: O(1) blocking host round trips
per GRAPH for the single-dispatch driver vs >= 1 per subset
(``cd_phase_round_trips`` / ``derived.cd_rt_graph_total``), and — with
the ISSUE 4 on-device DGM — the graph dispatch's traversed-wedge count
within 10% of the per-subset host-DGM driver's
(``derived.cd_graph_wedge_ratio``).

The ``wing`` section (PR 8, DESIGN.md §10) benches the EDGE-axis
decomposition on the same engine: per seeded graph, the host
``wing_bup_oracle`` wall vs both engine dispatch modes, blocking host
round trips (graph dispatch: O(1) per graph, no overflow surcharge),
the HUC recount fraction and exact psi checksums (gated bit-for-bit by
``scripts/bench_gate.py``).

The ``service`` section (PR 9, DESIGN.md §11) benches the serving
layer: incremental refresh vs warm full recompute on a <=5%-dirty
mutation ladder (the refresh must take the delta re-peel, stay
bit-exact and win on wall), plus warm-query p50/p99 latency with a
zero-dispatch cache-hit requirement.

The ``service_async`` section (PR 10, DESIGN.md §12) benches the
background scheduler: stale-read p50 with the flush worker on vs the
same-process inline drain wall (reads must not pay the refresh wall),
bit-exactness of the asynchronously refreshed result, and the
CacheGovernor eviction smoke (evict under a tiny budget, recompute
exactly).

Usage:  PYTHONPATH=src python benchmarks/bench_receipt.py [--quick] [--out F]
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import numpy as np

sys.path.insert(0, "src")


def _load_gate_constants():
    """Shared gate constants from scripts/bench_gate.py (loaded by file
    path — scripts/ is not a package, and prepending it to sys.path
    could shadow repro modules)."""
    import importlib.util

    path = Path(__file__).resolve().parent.parent / "scripts" / "bench_gate.py"
    spec = importlib.util.spec_from_file_location("bench_gate", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return (mod.OVF_RT_SURCHARGE, mod.WEDGE_RATIO_TOL,
            mod.MAP_DISPATCH_MIN_REDUCTION, mod.MAP_HIT_RATE_MIN,
            mod.TILED_WALL_MAX_RATIO, mod.WING_RT_BOUND,
            mod.SERVICE_REFRESH_WALL_MAX_RATIO,
            mod.SERVICE_WARM_QUERY_MAX_DISPATCHES,
            mod.SERVICE_ASYNC_STALE_MAX_RATIO)


(OVF_RT_SURCHARGE, WEDGE_RATIO_TOL,
 MAP_DISPATCH_MIN_REDUCTION, MAP_HIT_RATE_MIN,
 TILED_WALL_MAX_RATIO, WING_RT_BOUND,
 SERVICE_REFRESH_WALL_MAX_RATIO,
 SERVICE_WARM_QUERY_MAX_DISPATCHES,
 SERVICE_ASYNC_STALE_MAX_RATIO) = _load_gate_constants()

from datasets import DATASETS
from repro.core.graph import powerlaw_bipartite
from repro.core.peeling import bup_oracle
from repro.core.receipt import (
    ReceiptConfig,
    parb_tip_decompose,
    tip_decompose,
)
from repro.data.synthetic import interaction_graph

GRAPHS = [
    # (name, builder) — interaction graphs (KONECT-shaped power law) plus
    # the paper-regime dataset matrix (benchmarks/datasets.py, Table 2):
    # every entry gets the full engine suite AND the name-matched
    # deterministic-counter gates in scripts/bench_gate.py
    ("pl_small", lambda: interaction_graph(512, 256, 4_000, seed=7)),
    ("pl_medium", lambda: interaction_graph(1_024, 512, 8_000, seed=7)),
    ("pl_large", lambda: interaction_graph(2_048, 1_024, 16_000, seed=7)),
    ("itu_like", DATASETS["itu_like"]),
    ("tru_like", DATASETS["tru_like"]),
    ("dev_like", DATASETS["dev_like"]),
    ("orv_like", DATASETS["orv_like"]),
]

# dense-vs-tiled representation matrix: the regime graphs (tile
# occupancy near 1 — dense territory) plus genuinely sparse graphs
# above the Planner's min-size floor (occupancy << 1 — tiled territory).
# The measured crossover between the two cohorts is what the Planner's
# routing constants (repro/api/plan.py TILED_OCCUPANCY_CROSSOVER /
# TILED_MIN_DENSE_CELLS) must bracket; bench_gate.py enforces it.
REPRESENTATION_GRAPHS = [
    ("itu_like", DATASETS["itu_like"]),
    ("tru_like", DATASETS["tru_like"]),
    ("dev_like", DATASETS["dev_like"]),
    ("orv_like", DATASETS["orv_like"]),
    ("sp_quick", lambda: powerlaw_bipartite(1_024, 1_024, 6_000,
                                            alpha_u=2.0, alpha_v=2.0,
                                            seed=11)),
    # the sparse ladder that brackets the wall crossover: sp_mid is the
    # densest cell count where dense still wins (barely), sp_large is
    # where the tiled engine's O(n_slots) sweeps beat the dense matmul
    ("sp_mid", lambda: powerlaw_bipartite(4_096, 4_096, 24_000,
                                          alpha_u=2.0, alpha_v=2.0,
                                          seed=14)),
    ("sp_large", lambda: powerlaw_bipartite(8_192, 8_192, 32_000,
                                            alpha_u=2.0, alpha_v=2.0,
                                            seed=15)),
]
REPRESENTATION_QUICK = ("itu_like", "dev_like", "sp_quick")


def _stats_dict(stats) -> dict:
    return {
        "rho_cd": stats.rho_cd,
        "rho_fd": stats.rho_fd,
        "host_round_trips": stats.host_round_trips,
        "device_loop_calls": stats.device_loop_calls,
        "overflow_fallbacks": stats.overflow_fallbacks,
        "wedges_pvbcnt": stats.wedges_pvbcnt,
        "wedges_cd": stats.wedges_cd,
        "wedges_fd": stats.wedges_fd,
        "huc_recounts": stats.huc_recounts,
        "dgm_compactions": stats.dgm_compactions,
        "dgm_device_compactions": stats.dgm_device_compactions,
        "elided_sweeps": stats.elided_sweeps,
        "num_subsets": stats.num_subsets,
        "fd_groups": stats.fd_groups,
        "fd_padding_waste": stats.fd_padding_waste,
        "time_count_s": stats.time_count,
        "time_cd_s": stats.time_cd,
        "time_fd_s": stats.time_fd,
    }


def _run_engine(fn, *args, **kw):
    t0 = time.perf_counter()
    fn(*args, **kw)                      # cold: includes compilation
    cold = time.perf_counter() - t0
    warm = float("inf")
    fd_warm = float("inf")
    for _ in range(3):                   # warm: jit caches hot, best-of-3
        t0 = time.perf_counter()
        out, stats = fn(*args, **kw)
        warm = min(warm, time.perf_counter() - t0)
        fd_warm = min(fd_warm, stats.time_fd)
    return out, stats, cold, warm, fd_warm


def bench_graph(name: str, builder, *, partitions: int, check: bool) -> dict:
    g = builder()
    rec = {"name": name, "n_u": g.n_u, "n_v": g.n_v, "m": g.m,
           "num_partitions": partitions, "engines": {}}

    theta_ref = None
    if check:
        theta_ref, _ = bup_oracle(g)

    for label, runner, kw in (
        ("receipt_device", tip_decompose, dict(device_loop=True)),
        ("receipt_graph", tip_decompose, dict(device_loop=True,
                                              cd_dispatch="graph")),
        ("receipt_fd_b2", tip_decompose, dict(device_loop=True,
                                              fd_mode="b2")),
        ("receipt_host", tip_decompose, dict(device_loop=False)),
        ("parb_device", parb_tip_decompose, dict(device_loop=True)),
        ("parb_host", parb_tip_decompose, dict(device_loop=False)),
    ):
        cfg = ReceiptConfig(num_partitions=partitions, backend="xla", **kw)
        theta, stats, cold, warm, fd_warm = _run_engine(runner, g, cfg)
        if theta_ref is not None:
            assert (np.asarray(theta) == theta_ref).all(), (
                f"{name}/{label}: theta mismatch vs BUP oracle")
        rec["engines"][label] = {
            "wall_cold_s": cold, "wall_warm_s": warm,
            "time_fd_warm_s": fd_warm, **_stats_dict(stats),
        }
        print(f"  {label:15s} cold={cold:7.2f}s warm={warm:6.2f}s "
              f"fd={fd_warm*1e3:6.1f}ms RT={stats.host_round_trips:6d} "
              f"rho={stats.rho_cd:5d} rho_fd={stats.rho_fd:5d} "
              f"ovf={stats.overflow_fallbacks}", flush=True)

    # CD-phase-only round trips (the single-dispatch tentpole metric;
    # measured via receipt_cd so FD's per-group syncs don't blur it)
    from repro.core.receipt import RunStats, receipt_cd

    cd_rt = {}
    for disp in ("subset", "graph"):
        cfg = ReceiptConfig(num_partitions=partitions, backend="xla",
                            cd_dispatch=disp)
        s = RunStats()
        receipt_cd(g, cfg, s)
        cd_rt[disp] = {
            "host_round_trips": s.host_round_trips,
            "overflow_fallbacks": s.overflow_fallbacks,
            "num_subsets": s.num_subsets,
            "device_loop_calls": s.device_loop_calls,
            "wedges_cd": s.wedges_cd,
            "rho_cd": s.rho_cd,
            "huc_recounts": s.huc_recounts,
            "dgm_compactions": s.dgm_compactions,
            "dgm_device_compactions": s.dgm_device_compactions,
        }
    rec["cd_phase_round_trips"] = cd_rt
    print(f"  CD-only RTs: subset={cd_rt['subset']['host_round_trips']} "
          f"graph={cd_rt['graph']['host_round_trips']} "
          f"(ovf={cd_rt['graph']['overflow_fallbacks']}, "
          f"{cd_rt['graph']['num_subsets']} subsets, "
          f"{cd_rt['graph']['dgm_device_compactions']} device DGM)",
          flush=True)

    ed, eh = rec["engines"]["receipt_device"], rec["engines"]["receipt_host"]
    ef = rec["engines"]["receipt_fd_b2"]
    eg = rec["engines"]["receipt_graph"]
    pd, ph = rec["engines"]["parb_device"], rec["engines"]["parb_host"]
    n_sub = max(ed["num_subsets"], 1)
    rec["derived"] = {
        # whole-graph single-dispatch CD: O(1) RTs per graph
        "cd_rt_graph_total": cd_rt["graph"]["host_round_trips"],
        "cd_rt_subset_total": cd_rt["subset"]["host_round_trips"],
        "cd_graph_rt_reduction":
            cd_rt["subset"]["host_round_trips"]
            / max(cd_rt["graph"]["host_round_trips"], 1),
        "cd_graph_wall_warm_s": eg["wall_warm_s"],
        # on-device DGM: the graph dispatch's traversed wedges vs the
        # per-subset host-DGM driver's (the ISSUE 4 tentpole metric —
        # close to 1.0 now that c_rcnt is re-estimated per boundary)
        "cd_graph_wedges": cd_rt["graph"]["wedges_cd"],
        "cd_subset_wedges": cd_rt["subset"]["wedges_cd"],
        "cd_graph_wedge_ratio":
            cd_rt["graph"]["wedges_cd"]
            / max(cd_rt["subset"]["wedges_cd"], 1),
        "cd_graph_dgm_device": cd_rt["graph"]["dgm_device_compactions"],
        "cd_rt_per_subset_device": ed["host_round_trips"] / n_sub,
        "cd_rt_per_subset_host": eh["host_round_trips"] / n_sub,
        "cd_round_trip_reduction":
            eh["host_round_trips"] / max(ed["host_round_trips"], 1),
        "cd_wall_speedup_warm": eh["wall_warm_s"] / max(ed["wall_warm_s"],
                                                        1e-9),
        "parb_round_trip_reduction":
            ph["host_round_trips"] / max(pd["host_round_trips"], 1),
        "parb_wall_speedup_warm": ph["wall_warm_s"] / max(pd["wall_warm_s"],
                                                          1e-9),
        # FD level-peel vs the PR 1 sequential-peel baseline
        "fd_group_count": ed["fd_groups"],
        "fd_padding_waste": ed["fd_padding_waste"],
        "fd_rho_level": ed["rho_fd"],
        "fd_rho_seq": ef["rho_fd"],
        "fd_rho_reduction": ef["rho_fd"] / max(ed["rho_fd"], 1),
        "fd_wall_speedup_warm":
            ef["time_fd_warm_s"] / max(ed["time_fd_warm_s"], 1e-9),
    }
    d = rec["derived"]
    print(f"  -> RT reduction {d['cd_round_trip_reduction']:.1f}x "
          f"({d['cd_rt_per_subset_host']:.1f} -> "
          f"{d['cd_rt_per_subset_device']:.1f} per subset; "
          f"single-dispatch CD: {d['cd_rt_subset_total']} -> "
          f"{d['cd_rt_graph_total']} per graph, "
          f"wedge ratio {d['cd_graph_wedge_ratio']:.3f} vs subset DGM), "
          f"wall speedup {d['cd_wall_speedup_warm']:.2f}x, "
          f"ParB RT reduction {d['parb_round_trip_reduction']:.0f}x",
          flush=True)
    print(f"  -> FD: {d['fd_group_count']} groups, "
          f"{d['fd_padding_waste']*100:.0f}% padding waste, "
          f"rho_fd {d['fd_rho_seq']} -> {d['fd_rho_level']} "
          f"({d['fd_rho_reduction']:.1f}x fewer sweeps), "
          f"level-peel wall speedup {d['fd_wall_speedup_warm']:.2f}x",
          flush=True)
    return rec


def bench_representations(*, quick: bool, check: bool) -> dict:
    """Dense vs tiled representation matrix (ISSUE 7 tentpole).

    For each graph: the full dense CD+FD pipeline and the tiled
    whole-graph level-peel engine, both on the xla backend (CPU CI), with
    traversed-wedge counters and warm walls; plus the Planner's routing
    verdict for representation="auto" and its cost-model inputs.  The
    measured dense/tiled crossover (highest tiled-winning occupancy vs
    lowest dense-winning) is recorded so bench_gate.py can assert the
    Planner's routing constants bracket what was actually measured —
    the constants are provenanced here, never guessed.
    """
    from repro.api import EngineConfig, Planner
    from repro.api.plan import (
        TILED_MIN_DENSE_CELLS,
        TILED_OCCUPANCY_CROSSOVER,
    )

    names = REPRESENTATION_QUICK if quick else None
    records = []
    for name, builder in REPRESENTATION_GRAPHS:
        if names is not None and name not in names:
            continue
        g = builder()
        plan = Planner(EngineConfig(backend="xla")).plan(g)
        theta_ref = None
        if check and g.n_u * g.n_v <= 1 << 22:
            # the host oracle is O(n_u^2); on the big sparse-ladder
            # graphs the dense<->tiled bit-identity check below is the
            # (still exact) stand-in — the dense pipeline is itself
            # oracle-checked on every regime graph
            theta_ref, _ = bup_oracle(g)
        entry = {"name": name, "n_u": g.n_u, "n_v": g.n_v, "m": g.m,
                 "tile_occupancy": plan.cost_model["tile_occupancy"],
                 "dense_cells": plan.cost_model["dense_cells"],
                 # representation footprints (roofline_report --tiled)
                 "dense_bytes": plan.cost_model["dense_fixed_bytes"],
                 "tiled_bytes": plan.cost_model["tiled_bytes"],
                 "n_tiles": plan.cost_model["n_tiles"],
                 "routed": plan.representation}
        thetas = {}
        for label, rep in (("dense", "dense"), ("tiled", "tiled")):
            cfg = ReceiptConfig(backend="xla", representation=rep)
            theta, stats, cold, warm, _ = _run_engine(tip_decompose, g, cfg)
            thetas[label] = np.asarray(theta)
            if theta_ref is not None:
                assert (np.asarray(theta) == theta_ref).all(), (
                    f"{name}/{label}: theta mismatch vs BUP oracle")
            entry[label] = {
                "wall_cold_s": cold, "wall_warm_s": warm,
                "wedges_traversed": stats.wedges_cd + stats.wedges_fd,
                "rho": stats.rho_cd + stats.rho_fd,
            }
        if check:
            assert (thetas["dense"] == thetas["tiled"]).all(), (
                f"{name}: dense and tiled theta diverged")
        entry["wedge_ratio"] = (
            entry["tiled"]["wedges_traversed"]
            / max(entry["dense"]["wedges_traversed"], 1))
        entry["wall_ratio_warm"] = (
            entry["tiled"]["wall_warm_s"]
            / max(entry["dense"]["wall_warm_s"], 1e-9))
        records.append(entry)
        print(f"  {name:10s} occ={entry['tile_occupancy']:.3f} "
              f"routed={entry['routed']:5s} "
              f"wedges tiled/dense={entry['wedge_ratio']:.3f} "
              f"wall tiled/dense={entry['wall_ratio_warm']:.2f}", flush=True)

    tiled_wins = [r["tile_occupancy"] for r in records
                  if r["wall_ratio_warm"] <= 1.0]
    dense_wins = [r["tile_occupancy"] for r in records
                  if r["wall_ratio_warm"] > 1.0]
    rec = {
        "graphs": records,
        "occupancy_crossover": TILED_OCCUPANCY_CROSSOVER,
        "min_dense_cells": TILED_MIN_DENSE_CELLS,
        "measured": {
            # the wall-clock crossover bracket this run observed (None
            # when a side is empty, e.g. the quick subset)
            "max_tiled_win_occupancy": max(tiled_wins) if tiled_wins
            else None,
            "min_dense_win_occupancy": min(dense_wins) if dense_wins
            else None,
        },
    }
    print(f"[bench_receipt] representations: tiled wins up to occupancy "
          f"{rec['measured']['max_tiled_win_occupancy']}, dense wins from "
          f"{rec['measured']['min_dense_win_occupancy']} "
          f"(routing constant {TILED_OCCUPANCY_CROSSOVER})", flush=True)
    return rec


WING_GRAPHS = [
    # seeded graphs sized for the O(m * butterflies) host oracle, so the
    # engine-vs-oracle wall comparison is measured, not extrapolated
    ("wing_pl_small", lambda: powerlaw_bipartite(160, 96, 1_000,
                                                 alpha_u=2.0, alpha_v=2.0,
                                                 seed=21)),
    ("wing_itu_mini", lambda: interaction_graph(192, 128, 1_400, seed=23)),
]
WING_QUICK = ("wing_pl_small",)


def bench_wing(*, quick: bool, check: bool, partitions: int = 8) -> dict:
    """Edge-axis (wing / bitruss) decomposition on the shared peel engine
    (PR 8, DESIGN.md §10) vs the sequential host oracle.

    Per seeded graph: the host oracle wall (``wing_bup_oracle``, one peel
    round per support level) and both engine dispatch modes, with the
    counters the gate pins — blocking host round trips (the graph
    dispatch must stay O(1): the full-mask edge peel has no overflow
    path), the recount fraction (which HUC arm the edge axis actually
    takes — the paper's argument that recount matters MORE for edge
    peeling, made measurable) and exact psi checksums (deterministic
    graphs, so ``bench_gate.py`` gates them bit-for-bit)."""
    from repro.core.engine import wing_decompose_engine
    from repro.core.wing import wing_bup_oracle

    records = []
    for name, builder in WING_GRAPHS:
        if quick and name not in WING_QUICK:
            continue
        g = builder()
        t0 = time.perf_counter()
        psi_ref, oracle_rounds = wing_bup_oracle(g)
        oracle_wall = time.perf_counter() - t0
        entry = {"name": name, "n_u": g.n_u, "n_v": g.n_v, "m": g.m,
                 "oracle_wall_s": oracle_wall,
                 "oracle_rounds": oracle_rounds,
                 "max_psi": int(psi_ref.max(initial=0)),
                 "psi_checksum": int(psi_ref.sum()),
                 "engines": {}}
        for disp in ("subset", "graph"):
            cfg = ReceiptConfig(num_partitions=partitions, backend="xla",
                                cd_dispatch=disp)
            psi, stats, cold, warm, _ = _run_engine(
                wing_decompose_engine, g, cfg)
            if check:
                assert (np.asarray(psi) == psi_ref).all(), (
                    f"{name}/{disp}: psi mismatch vs wing BUP oracle")
            sweeps = stats.rho_cd + stats.rho_fd
            entry["engines"][disp] = {
                "wall_cold_s": cold, "wall_warm_s": warm,
                "host_round_trips": stats.host_round_trips,
                "rho": sweeps,
                "huc_recounts": stats.huc_recounts,
                "recount_fraction": stats.huc_recounts / max(sweeps, 1),
                "oracle_speedup_warm": oracle_wall / max(warm, 1e-9),
            }
            e = entry["engines"][disp]
            print(f"  wing/{disp:6s} cold={cold:6.2f}s warm={warm:5.2f}s "
                  f"RT={e['host_round_trips']:3d} rho={e['rho']:4d} "
                  f"recount={e['recount_fraction']:.2f} "
                  f"oracle x{e['oracle_speedup_warm']:.1f} "
                  f"(oracle {oracle_wall:.2f}s, {oracle_rounds} rounds)",
                  flush=True)
        records.append(entry)
    return {"graphs": records, "rt_bound": WING_RT_BOUND}


def bench_executor_map(*, n_graphs: int = 12, check: bool = True) -> dict:
    """Multi-graph batched decomposition (PR 5): ``Executor.map`` over a
    fleet of small cohort graphs vs a sequential per-graph
    ``tip_decompose`` loop.  Reported: wall (cold = first map call incl.
    tracing, warm = second fleet of the same shapes — pure cache hits),
    device-dispatch counts (deterministic; gated by bench_gate.py) and
    the warm cache hit rate."""
    from repro.api import Executor
    from repro.core.receipt import ReceiptConfig, tip_decompose

    cfg = ReceiptConfig(num_partitions=4, backend="xla")
    mk = lambda seed0: [interaction_graph(160, 96, 1100, seed=seed0 + s)
                        for s in range(n_graphs)]
    graphs = mk(100)

    # sequential per-graph pipeline (the pre-PR-5 serving shape)
    t0 = time.perf_counter()
    seq = [tip_decompose(g, cfg) for g in graphs]
    seq_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    seq = [tip_decompose(g, cfg) for g in graphs]
    seq_warm = time.perf_counter() - t0
    seq_dispatches = sum(s.device_loop_calls + s.host_round_trips
                         for _, s in seq)

    ex = Executor(cfg)
    t0 = time.perf_counter()
    tds = ex.map(graphs)
    map_cold = time.perf_counter() - t0
    rep_cold = dict(ex.last_map_report)
    if check:
        for (t_seq, _), td in zip(seq, tds):
            assert (np.asarray(t_seq) == td.theta).all(), (
                "Executor.map theta mismatch vs per-graph tip_decompose")
    # warm: a SECOND fleet of the same bucketed shapes — executables and
    # measured sizing come entirely out of the cache
    fleet2 = mk(500)
    t0 = time.perf_counter()
    ex.map(fleet2)
    map_warm = time.perf_counter() - t0
    rep_warm = dict(ex.last_map_report)
    hits = rep_warm["cache_hits"]
    hit_rate = hits / max(hits + rep_warm["cache_misses"], 1)

    # guardrail overhead (PR 6): the hardened warm path (input
    # validation, fault-point consults, fallback wrapping, straggler
    # timing) vs the bare guardrails=False path, measured in the SAME
    # process on the SAME warm fleet (min of repeats) so the gate's
    # ratio is not at the mercy of cross-run CI noise
    ex_bare = Executor(cfg, guardrails=False)
    ex_bare.map(fleet2)                      # warm the bare executor
    guarded_w, bare_w = [], []
    for _ in range(3):
        t0 = time.perf_counter()
        ex.map(fleet2)
        guarded_w.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        ex_bare.map(fleet2)
        bare_w.append(time.perf_counter() - t0)
    guarded_wall, bare_wall = min(guarded_w), min(bare_w)
    guardrail_overhead = guarded_wall / max(bare_wall, 1e-9) - 1.0
    map_dispatches = (rep_cold["device_loop_calls"]
                      + rep_cold["counting_dispatches"]
                      + rep_cold["host_round_trips"])
    rec = {
        "n_graphs": n_graphs,
        "groups": rep_cold["groups"],
        "chunks": rep_cold["chunks"],
        "seq_wall_cold_s": seq_cold,
        "seq_wall_warm_s": seq_warm,
        "map_wall_cold_s": map_cold,
        "map_wall_warm_s": map_warm,
        "map_wall_speedup_warm": seq_warm / max(map_warm, 1e-9),
        "seq_dispatches": seq_dispatches,
        "map_dispatches": map_dispatches,
        "dispatch_reduction": seq_dispatches / max(map_dispatches, 1),
        "warm_cache_hit_rate": hit_rate,
        "guarded_wall_warm_s": guarded_wall,
        "bare_wall_warm_s": bare_wall,
        "guardrail_overhead": guardrail_overhead,
    }
    print(f"[bench_receipt] executor_map: {n_graphs} graphs, "
          f"{rec['chunks']} chunk(s): dispatches {seq_dispatches} -> "
          f"{map_dispatches} ({rec['dispatch_reduction']:.1f}x fewer), "
          f"wall warm {seq_warm:.2f}s -> {map_warm:.2f}s "
          f"({rec['map_wall_speedup_warm']:.1f}x), warm hit rate "
          f"{hit_rate:.0%}, guardrail overhead "
          f"{guardrail_overhead:+.1%}", flush=True)
    return rec


def _service_mutations(g, count, rng):
    """``count`` inserts absent from ``g`` + ``count`` present deletes,
    both biased to LOW-degree endpoints (the regime where the adaptive
    stop ladder stays low and partial re-peels actually happen — the
    serving layer's target traffic: cold users/items churn, the dense
    core is stable)."""
    du = np.bincount(g.edges_u, minlength=g.n_u)
    dv = np.bincount(g.edges_v, minlength=g.n_v)
    u_pool = np.argsort(du)[: max(8, g.n_u // 4)]
    v_pool = np.argsort(dv)[: max(8, g.n_v // 4)]
    have = set((g.edges_u.astype(np.int64) * g.n_v
                + g.edges_v).tolist())
    ins = []
    while len(ins) < count:
        u = int(rng.choice(u_pool))
        v = int(rng.choice(v_pool))
        k = u * g.n_v + v
        if k not in have:
            have.add(k)
            ins.append((u, v))
    score = du[g.edges_u] + dv[g.edges_v]
    drop = np.argsort(score)[:count]
    return np.array(ins, np.int64), drop


def bench_service(*, quick: bool, check: bool, partitions: int = 8) -> dict:
    """Serving layer (PR 9, DESIGN.md §11): incremental refresh vs full
    recompute on a dirty-fraction ladder, plus warm-query latency.

    Per rung: re-ingest the seed graph, run the full decompose (primes
    the CD-bound stop ladder), one warm-up mutation round (compiles the
    prefix-peel loops at these shapes), then a MEASURED round — wall of
    ``flush()`` draining the coalesced refresh vs a warm from-scratch
    ``Executor.decompose`` of the same mutated graph in the same
    process.  The refresh must take the delta path, stay bit-exact and
    beat the full wall (gated here and by scripts/bench_gate.py).  The
    warm-query loop then times repeat reads of the fresh dataset: p50 /
    p99 latency and the number of flush-dispatching misses (must be
    <= SERVICE_WARM_QUERY_MAX_DISPATCHES — fresh reads are pure cache
    hits, zero device work)."""
    from repro.api import EngineConfig
    from repro.service import DecompositionService, ServiceConfig

    n_u, n_v, m = (128, 96, 1100) if quick else (256, 160, 2600)
    fracs = (0.02,) if quick else (0.01, 0.02, 0.05)
    g0 = interaction_graph(n_u, n_v, m, seed=31)
    cfg = EngineConfig(num_partitions=partitions, backend="xla")
    # threshold above the ladder's top rung so every rung exercises the
    # delta path (the threshold fallback has its own test coverage)
    svc = DecompositionService(cfg, ServiceConfig(
        refresh_dirty_threshold=0.12))
    ex = svc._executor("tip")
    rng = np.random.default_rng(5)
    name = "bench"

    ladder = []
    for frac in fracs:
        k = max(1, int(round(frac * g0.m / 2)))
        svc.ingest(name, g0, workload="tip", replace=True)
        svc.flush(name)                 # full run: primes the CD bounds
        for measured in (False, True):  # warm-up round, then measured
            g = svc._datasets[name].graph
            ins, drop = _service_mutations(g, k, rng)
            svc.insert_edges(name, ins[:, 0], ins[:, 1])
            svc.delete_edges(name, g.edges_u[drop], g.edges_v[drop])
            t0 = time.perf_counter()
            svc.flush(name)
            refresh_wall = time.perf_counter() - t0
        ds = svc._datasets[name]
        stats = ds.result.stats
        full_wall = float("inf")
        for _ in range(2):              # warm from-scratch comparator
            t0 = time.perf_counter()
            ref = ex.decompose(ds.graph)
            full_wall = min(full_wall, time.perf_counter() - t0)
        exact = bool((np.asarray(ds.result.numbers)
                      == np.asarray(ref.numbers)).all())
        if check:
            assert exact, (f"service refresh diverged from from-scratch "
                           f"decompose at dirty={frac}")
        stop = stats.refresh_stop
        rung = {
            "dirty_frac": frac,
            "dirty_edges": stats.refresh_dirty_edges,
            "mode": stats.refresh_mode,
            "stop": None if stop == float("inf") else stop,
            "subsets_repeeled": stats.refresh_subsets_repeeled,
            "subsets_total": stats.refresh_subsets_total,
            "refresh_dispatches": (stats.device_loop_calls
                                   + stats.host_round_trips),
            "refresh_wall_s": refresh_wall,
            "full_wall_s": full_wall,
            "refresh_speedup": full_wall / max(refresh_wall, 1e-9),
            "exact": exact,
        }
        ladder.append(rung)
        print(f"  dirty={frac:4.0%} ({rung['dirty_edges']:3d} edges) "
              f"mode={rung['mode']:5s} subsets="
              f"{rung['subsets_repeeled']}/{rung['subsets_total']} "
              f"refresh={refresh_wall:.3f}s full={full_wall:.3f}s "
              f"({rung['refresh_speedup']:.1f}x) exact={exact}",
              flush=True)

    # warm-query loop on the (fresh) dataset: every read is a cache hit
    before = svc.report()["datasets"][name]
    n_queries = 200
    lat = []
    for _ in range(n_queries):
        t0 = time.perf_counter()
        svc.query(name)
        lat.append(time.perf_counter() - t0)
    after = svc.report()["datasets"][name]
    hits = after["query_hits"] - before["query_hits"]
    warm_query = {
        "queries": n_queries,
        "hits": hits,
        # a non-hit read drains the queue: at most one dispatch batch
        "dispatching_misses": n_queries - hits,
        "p50_ms": float(np.percentile(lat, 50) * 1e3),
        "p99_ms": float(np.percentile(lat, 99) * 1e3),
    }
    print(f"[bench_receipt] service: warm query p50="
          f"{warm_query['p50_ms']:.3f}ms p99={warm_query['p99_ms']:.3f}ms "
          f"hits={hits}/{n_queries}", flush=True)
    return {"workload": "tip", "n_u": n_u, "n_v": n_v, "m": g0.m,
            "num_partitions": partitions, "ladder": ladder,
            "warm_query": warm_query}


def bench_service_async(*, quick: bool, check: bool,
                        partitions: int = 8) -> dict:
    """Background scheduler (PR 10, DESIGN.md §12): stale-read latency
    with the flush worker on vs the same-process inline drain wall,
    async refresh exactness, and the CacheGovernor eviction smoke.

    The comparator is PR 9's inline mode measured first in the same
    process (ingest → full → warm-up round → measured mutation round
    whose ``flush()`` wall is the drain the worker absorbs).  The async
    service then runs the same traffic with the worker on: each
    measured read lands right after a mutation batch and must return
    non-blocking — a counted stale read or a cache hit, never an
    inline drain on the query thread — while the worker refreshes in
    the background; after ``wait_until_idle`` the read must observe the
    new version, bit-exact against a from-scratch decompose."""
    from repro.api import EngineConfig, Executor
    from repro.service import DecompositionService, ServiceConfig

    n_u, n_v, m = (128, 96, 1100) if quick else (256, 160, 2600)
    rounds = 4 if quick else 8
    g0 = interaction_graph(n_u, n_v, m, seed=37)
    cfg = EngineConfig(num_partitions=partitions, backend="xla")
    name = "bench"
    k = max(1, int(round(0.02 * m / 2)))

    # inline comparator (PR 9 semantics): the drain wall a stale read
    # used to pay, measured warm in this process
    inline = DecompositionService(cfg, ServiceConfig(
        refresh_dirty_threshold=0.12))
    inline.ingest(name, g0, workload="tip")
    inline.flush(name)
    rng = np.random.default_rng(6)
    inline_wall = float("inf")
    for _ in range(2):                  # warm-up round, then measured
        g = inline._datasets[name].graph
        ins, drop = _service_mutations(g, k, rng)
        inline.insert_edges(name, ins[:, 0], ins[:, 1])
        inline.delete_edges(name, g.edges_u[drop], g.edges_v[drop])
        t0 = time.perf_counter()
        inline.flush(name)
        inline_wall = time.perf_counter() - t0

    # async service: same traffic, worker on
    svc = DecompositionService(cfg, ServiceConfig(
        refresh_dirty_threshold=0.12, background=True,
        worker_poll_s=0.005))
    svc.ingest(name, g0, workload="tip")
    svc.query(name, wait=True, timeout=600)
    before = svc.report()["datasets"][name]
    rng = np.random.default_rng(6)      # same mutation stream
    lat = []
    for _ in range(rounds):
        g = svc._datasets[name].graph
        ins, drop = _service_mutations(g, k, rng)
        svc.insert_edges(name, ins[:, 0], ins[:, 1])
        svc.delete_edges(name, g.edges_u[drop], g.edges_v[drop])
        t0 = time.perf_counter()
        svc.query(name, with_info=True)     # must not pay the drain
        lat.append(time.perf_counter() - t0)
        assert svc.wait_until_idle(timeout=600), \
            "background worker failed to drain between rounds"
    after = svc.report()["datasets"][name]
    stale = after["stale_reads"] - before["stale_reads"]
    hits = after["query_hits"] - before["query_hits"]
    dec, info = svc.query(name, with_info=True)
    ref = Executor(cfg).decompose(svc._datasets[name].graph)
    async_exact = bool((np.asarray(dec.numbers)
                        == np.asarray(ref.numbers)).all())
    if check:
        assert async_exact, ("background-refreshed numbers diverged "
                             "from from-scratch decompose")
    worker = svc.report()["worker"]
    svc.close()
    stale_read = {
        "rounds": rounds,
        "stale_reads": stale,
        "hits": hits,
        # reads that were neither a hit nor a counted stale read paid
        # a drain/wait on the query thread — the wall the worker must
        # absorb (gated to zero by scripts/bench_gate.py)
        "blocking_reads": rounds - stale - hits,
        "p50_s": float(np.percentile(lat, 50)),
        "p99_s": float(np.percentile(lat, 99)),
    }

    # eviction smoke: tiny budget forces LRU eviction; the evicted
    # dataset must recompute bit-exactly on demand
    ev = DecompositionService(cfg, ServiceConfig(cache_budget_bytes=64))
    g1 = interaction_graph(64, 48, 480, seed=38)
    ev.ingest("a", g1)
    ev.ingest("b", interaction_graph(56, 44, 420, seed=39))
    ev.query("a")
    ev.query("b")                       # evicts a (budget < any result)
    evictions = ev.cache_report()["evicted_total"]
    dec_a = ev.query("a")               # recompute on demand
    ref_a = Executor(cfg).decompose(ev._datasets["a"].graph)
    ev_exact = bool((np.asarray(dec_a.numbers)
                     == np.asarray(ref_a.numbers)).all())
    if check:
        assert evictions >= 1, "eviction smoke evicted nothing"
        assert ev_exact, "post-eviction recompute diverged"

    print(f"[bench_receipt] service_async: stale read p50="
          f"{stale_read['p50_s'] * 1e3:.3f}ms vs inline drain "
          f"{inline_wall * 1e3:.1f}ms ({stale}/{rounds} stale, "
          f"{hits} hits, {stale_read['blocking_reads']} blocking), "
          f"worker cycles={worker['cycles']}, evictions={evictions} "
          f"exact={async_exact and ev_exact}", flush=True)
    return {
        "workload": "tip", "n_u": n_u, "n_v": n_v, "m": g0.m,
        "num_partitions": partitions,
        "inline_drain_wall_s": inline_wall,
        "stale_read": stale_read,
        "async_exact": async_exact,
        "fresh_after_idle": bool(info["fresh"]),
        "worker": {"cycles": worker["cycles"],
                   "crashes": worker["crashes"]},
        "eviction": {"evictions": evictions, "exact": ev_exact},
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_receipt.json")
    ap.add_argument("--quick", action="store_true",
                    help="smallest graph only (CI smoke)")
    ap.add_argument("--partitions", type=int, default=16)
    ap.add_argument("--no-check", action="store_true",
                    help="skip the BUP oracle verification")
    args = ap.parse_args(argv)

    graphs = GRAPHS[:1] if args.quick else GRAPHS
    results = []
    for name, builder in graphs:
        print(f"[bench_receipt] {name}", flush=True)
        results.append(bench_graph(
            name, builder, partitions=args.partitions,
            check=not args.no_check,
        ))

    print("[bench_receipt] representations (dense vs tiled)", flush=True)
    representations = bench_representations(
        quick=args.quick, check=not args.no_check)

    print("[bench_receipt] wing (edge-axis decomposition, DESIGN.md §10)",
          flush=True)
    wing = bench_wing(quick=args.quick, check=not args.no_check)

    exec_map = bench_executor_map(
        n_graphs=8 if args.quick else 12, check=not args.no_check)

    print("[bench_receipt] service (incremental refresh, DESIGN.md §11)",
          flush=True)
    service = bench_service(quick=args.quick, check=not args.no_check)

    print("[bench_receipt] service_async (background scheduler, "
          "DESIGN.md §12)", flush=True)
    service_async = bench_service_async(
        quick=args.quick, check=not args.no_check)

    payload = {
        "benchmark": "receipt_peel_engine",
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "backend": "xla (CPU)",
        "graphs": results,
        "representations": representations,
        "wing": wing,
        "executor_map": exec_map,
        "service": service,
        "service_async": service_async,
    }
    Path(args.out).write_text(json.dumps(payload, indent=2))
    print(f"[bench_receipt] wrote {args.out}")

    largest = results[-1]["derived"]
    largest_cd = results[-1]["cd_phase_round_trips"]["graph"]
    ok = (largest["cd_round_trip_reduction"] >= 5.0
          and largest["fd_rho_reduction"] > 1.0
          # single-dispatch CD: O(1) RTs per graph (2 + a bounded
          # overflow surcharge), independent of the subset count
          and largest_cd["host_round_trips"]
          <= 2 + OVF_RT_SURCHARGE * largest_cd["overflow_fallbacks"]
          # multi-graph batched decomposition: deterministic dispatch
          # counts and a fully-cached warm fleet (the PR 5 acceptance)
          and exec_map["dispatch_reduction"] >= MAP_DISPATCH_MIN_REDUCTION
          and exec_map["warm_cache_hit_rate"] >= MAP_HIT_RATE_MIN)
    # on-device DGM: every benched graph must keep the O(1)-RT claim AND
    # land its traversed-wedge count within WEDGE_RATIO_TOL of the
    # per-subset host-DGM driver's (the ISSUE 4 acceptance gate)
    for r in results:
        cd = r["cd_phase_round_trips"]["graph"]
        rt_ok = (cd["host_round_trips"]
                 <= 2 + OVF_RT_SURCHARGE * cd["overflow_fallbacks"])
        wedge_ok = r["derived"]["cd_graph_wedge_ratio"] <= WEDGE_RATIO_TOL
        if not (rt_ok and wedge_ok):
            print(f"[bench_receipt] {r['name']}: graph-dispatch gate "
                  f"FAILED (rt_ok={rt_ok}, wedge_ratio="
                  f"{r['derived']['cd_graph_wedge_ratio']:.3f})")
        ok = ok and rt_ok and wedge_ok
    # edge axis (PR 8 acceptance): the graph-dispatch wing driver keeps
    # O(1) blocking round trips per graph — no overflow surcharge, the
    # full-mask edge peel has no overflow path (psi exactness is already
    # asserted against the wing oracle inside bench_wing)
    for r in wing["graphs"]:
        w_rt = r["engines"]["graph"]["host_round_trips"]
        if w_rt > WING_RT_BOUND:
            print(f"[bench_receipt] {r['name']}: wing graph-dispatch gate "
                  f"FAILED (host_round_trips={w_rt} > {WING_RT_BOUND})")
        ok = ok and w_rt <= WING_RT_BOUND
    # tiled representation (ISSUE 7 acceptance): on every graph the cost
    # model routes tiled, the tiled engine must traverse no more wedges
    # than the dense pipeline and keep warm wall within the gate ratio
    for r in representations["graphs"]:
        if r["routed"] != "tiled":
            continue
        t_ok = (r["wedge_ratio"] <= 1.0
                and r["wall_ratio_warm"] <= TILED_WALL_MAX_RATIO)
        if not t_ok:
            print(f"[bench_receipt] {r['name']}: tiled-representation "
                  f"gate FAILED (wedge_ratio={r['wedge_ratio']:.3f}, "
                  f"wall_ratio={r['wall_ratio_warm']:.2f})")
        ok = ok and t_ok
    # serving layer (PR 9 acceptance): every ladder rung stays on the
    # delta path, exact, and beats the same-process full-recompute wall;
    # the warm-query loop serves from the cached decomposition
    for r in service["ladder"]:
        s_ok = (r["mode"] == "delta" and r["exact"]
                and r["refresh_wall_s"]
                <= r["full_wall_s"] * SERVICE_REFRESH_WALL_MAX_RATIO)
        if not s_ok:
            print(f"[bench_receipt] service dirty={r['dirty_frac']}: "
                  f"gate FAILED (mode={r['mode']}, exact={r['exact']}, "
                  f"refresh={r['refresh_wall_s']:.3f}s vs "
                  f"full={r['full_wall_s']:.3f}s)")
        ok = ok and s_ok
    if (service["warm_query"]["dispatching_misses"]
            > SERVICE_WARM_QUERY_MAX_DISPATCHES):
        print(f"[bench_receipt] service: warm-query gate FAILED "
              f"({service['warm_query']['dispatching_misses']} "
              f"dispatching misses)")
        ok = False
    # background scheduler (PR 10 acceptance): every measured read
    # serves non-blocking, stale-read p50 stays far under the inline
    # drain wall, the async refresh is exact, eviction recomputes
    sa_sr = service_async["stale_read"]
    sa_ok = (sa_sr["blocking_reads"] == 0
             and sa_sr["p50_s"] <= service_async["inline_drain_wall_s"]
             * SERVICE_ASYNC_STALE_MAX_RATIO
             and service_async["async_exact"]
             and service_async["fresh_after_idle"]
             and service_async["eviction"]["evictions"] >= 1
             and service_async["eviction"]["exact"])
    if not sa_ok:
        print(f"[bench_receipt] service_async: gate FAILED "
              f"(blocking={sa_sr['blocking_reads']}, "
              f"p50={sa_sr['p50_s'] * 1e3:.3f}ms vs inline "
              f"{service_async['inline_drain_wall_s'] * 1e3:.1f}ms, "
              f"exact={service_async['async_exact']}, "
              f"eviction={service_async['eviction']})")
    ok = ok and sa_ok
    if not args.quick:
        # wall-clock criteria run on the FULL bench only: --quick is the
        # per-push CI smoke (scripts/ci.sh quick fails on this exit
        # code), and shared runners are too noisy to gate on wall time —
        # the deterministic counters above carry the regression signal
        # there (scripts/bench_gate.py makes the same call).  The FD
        # wall criterion targets the LARGEST graph (small stacks are
        # dominated by fixed dispatch costs, where the sequential
        # baseline's single fori_loop is hard to beat on CPU); the
        # deterministic FD signal is fd_rho_reduction (checked above);
        # on CPU the FD gate allows 10% scheduler noise — the two
        # engines are flop-parity there and the level-peel win is
        # structural on latency-bound accelerators.
        ok = (ok and largest["cd_wall_speedup_warm"] > 1.0
              and largest["fd_wall_speedup_warm"] > 0.9)
    print(f"[bench_receipt] largest graph: "
          f"{largest['cd_round_trip_reduction']:.1f}x fewer host round "
          f"trips, {largest['cd_wall_speedup_warm']:.2f}x warm wall "
          f"speedup, FD level-peel {largest['fd_wall_speedup_warm']:.2f}x "
          f"wall / {largest['fd_rho_reduction']:.1f}x fewer sweeps "
          f"-> {'OK' if ok else 'BELOW TARGET'}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
